"""Quartic solver + landing polynomial (Lemma 3.1) properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import quartic, stiefel


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-5, 5), min_size=4, max_size=4), st.floats(0.1, 3.0))
def test_quartic_roots_from_known_roots(roots, scale):
    r = np.array(roots)
    # coefficients of scale * prod (x - r_i)
    coeffs = scale * np.poly(r)  # degree-4 monic * scale
    a, b, c, d, e = (jnp.asarray(x, jnp.float32) for x in coeffs)
    found = np.asarray(quartic.solve_quartic(a, b, c, d, e))
    # every true root is close to some found root
    err = np.abs(r[:, None] - found[None, :]).min(axis=1).max()
    span = 1 + np.abs(r).max()
    assert err < 5e-2 * span


def test_cubic_roots():
    # (x-1)(x-2)(x-3) = x^3 - 6x^2 + 11x - 6
    roots = np.sort(
        np.real(np.asarray(quartic.solve_cubic(
            jnp.array(1.0), jnp.array(-6.0), jnp.array(11.0), jnp.array(-6.0)
        )))
    )
    np.testing.assert_allclose(roots, [1.0, 2.0, 3.0], atol=1e-4)


def test_landing_polynomial_matches_bruteforce():
    """P(lam) from Lemma-3.1 coefficients == directly-evaluated distance^2."""
    key = jax.random.PRNGKey(0)
    x = stiefel.random_stiefel(key, (5, 12))
    g = jax.random.normal(jax.random.PRNGKey(1), (5, 12))
    m = x - 0.2 * stiefel.riemannian_gradient(x, g)
    coeffs = quartic.landing_poly_coeffs(m)
    for lam in [0.0, 0.3, 0.5, 0.9, 1.5]:
        x1 = m + lam * (jnp.eye(5) - m @ m.T) @ m
        direct = float(stiefel.manifold_distance(x1)) ** 2
        poly = float(quartic.eval_quartic(coeffs, jnp.asarray(lam)))
        np.testing.assert_allclose(poly, direct, rtol=1e-3, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**30), eta=st.floats(0.05, 0.8))
def test_optimal_lambda_beats_or_matches_half(seed, eta):
    """The quartic root lands at least as close as lam = 1/2."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = stiefel.random_stiefel(k1, (4, 10))
    g = jax.random.normal(k2, (4, 10))
    g = g / jnp.maximum(jnp.linalg.norm(g), 1.0)
    m = x - eta * stiefel.riemannian_gradient(x, g)
    lam = quartic.optimal_lambda(m)
    coeffs = quartic.landing_poly_coeffs(m)
    p_root = float(quartic.eval_quartic(coeffs, lam))
    p_half = float(quartic.eval_quartic(coeffs, jnp.asarray(0.5)))
    # 1/2 is always kept as a candidate so the root can only match or beat
    # it, up to fp32 evaluation noise near the polynomial's floor
    assert p_root <= p_half * 1.5 + 1e-6


def test_optimal_lambda_near_half_when_xi_small():
    """Prop 3.3: small xi => lambda* ~ 1/2."""
    key = jax.random.PRNGKey(3)
    x = stiefel.random_stiefel(key, (6, 16))
    g = jax.random.normal(jax.random.PRNGKey(4), (6, 16))
    g = 0.1 * g / jnp.linalg.norm(g)
    m = x - 0.1 * stiefel.riemannian_gradient(x, g)
    lam = float(quartic.optimal_lambda(m))
    assert abs(lam - 0.5) < 0.2


def test_degenerate_on_manifold_falls_back():
    x = stiefel.random_stiefel(jax.random.PRNGKey(5), (4, 8))
    lam = quartic.optimal_lambda(x)  # M already on manifold
    assert np.isfinite(float(lam))


def _gram_dev(m, pv=None):
    p = m.shape[-2]
    if pv is None:
        eye = jnp.eye(p, dtype=m.dtype)
    else:
        eye = stiefel.masked_eye(p, pv, m.dtype)
    return m @ jnp.conj(jnp.swapaxes(m, -1, -2)) - eye


def test_coeffs_from_gram_match_direct():
    """The gram-powers route (two Bp^3 matmuls, what the watchdog's
    blended land uses in-graph) reproduces the direct Lemma-3.1
    coefficients from the (B, p, n) stack."""
    key = jax.random.PRNGKey(7)
    x = stiefel.random_stiefel(key, (3, 6, 12))
    g = jax.random.normal(jax.random.PRNGKey(8), (3, 6, 12))
    m = 1.2 * x - 0.2 * g  # off-manifold: every coefficient nonzero
    direct = quartic.landing_poly_coeffs(m)
    fromg = quartic.landing_poly_coeffs_from_gram(_gram_dev(m))
    for a, b in zip(direct, fromg):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6
        )


def test_optimal_lambda_from_gram_matches_direct():
    """Same root from either coefficient route — real, ragged (pv) and
    complex stacks."""
    key = jax.random.PRNGKey(9)
    x = stiefel.random_stiefel(key, (4, 6, 12))
    g = jax.random.normal(jax.random.PRNGKey(10), (4, 6, 12))
    m = 1.5 * x - 0.1 * g
    lam_a = np.asarray(quartic.optimal_lambda(m))
    lam_b = np.asarray(quartic.optimal_lambda_from_gram(_gram_dev(m)))
    np.testing.assert_allclose(lam_a, lam_b, rtol=1e-4, atol=1e-5)

    pv = jnp.array([6, 4, 3, 6])  # ragged: padded rows masked out
    mz = m * (jnp.arange(6)[None, :, None] < pv[:, None, None])
    lam_a = np.asarray(quartic.optimal_lambda(mz, pv=pv))
    lam_b = np.asarray(quartic.optimal_lambda_from_gram(_gram_dev(mz, pv)))
    np.testing.assert_allclose(lam_a, lam_b, rtol=1e-4, atol=1e-5)

    kr, ki = jax.random.split(jax.random.PRNGKey(11))
    mc = (jax.random.normal(kr, (2, 5, 9)).astype(jnp.complex64)
          + 1j * jax.random.normal(ki, (2, 5, 9)).astype(jnp.complex64))
    mc = 0.4 * mc
    lam_a = np.asarray(quartic.optimal_lambda(mc))
    lam_b = np.asarray(quartic.optimal_lambda_from_gram(_gram_dev(mc)))
    np.testing.assert_allclose(lam_a, lam_b, rtol=1e-4, atol=1e-5)
