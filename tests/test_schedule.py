"""Ragged megagroup scheduler: plan_groups edge cases (ISSUE-5 satellite)
and the padding-waste-vs-dispatch-count cost model in core/schedule.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule, stiefel
from repro.core.api import plan_groups
from repro.core.schedule import (
    DISPATCH_OVERHEAD_BYTES,
    aligned_stack_bytes,
    plan_megagroups,
)

KEY = jax.random.PRNGKey(0)


def _plan(tree, grouping):
    leaves, treedef = jax.tree.flatten(tree)
    return plan_groups(leaves, treedef, grouping)


# ------------------------------------------------------- plan_groups edges


def test_single_leaf_tree_is_one_uniform_group_every_mode():
    tree = {"only": stiefel.random_stiefel(KEY, (4, 16))}
    for grouping in ("auto", "per_leaf", "padded"):
        plan = _plan(tree, grouping)
        assert len(plan.groups) == 1
        (g,) = plan.groups
        assert (g.p, g.n, g.batch) == (4, 16, 1)
        assert not g.ragged and g.valid_shape_arrays() is None
        assert plan.n_matrices == 1


def test_complex_dtype_never_buckets_next_to_real():
    """Same manifold shape, different dtype: separate exact buckets AND
    separate megagroups — a complex matrix never shares a padded dispatch
    with a real one (the fused path is real-only and the update algebra
    differs)."""
    tree = {
        "r1": stiefel.random_stiefel(KEY, (4, 16)),
        "c1": stiefel.random_stiefel(jax.random.PRNGKey(1), (4, 16), jnp.complex64),
        "r2": stiefel.random_stiefel(jax.random.PRNGKey(2), (4, 12)),
        "c2": stiefel.random_stiefel(jax.random.PRNGKey(3), (4, 12), jnp.complex64),
    }
    auto = _plan(tree, "auto")
    assert len(auto.groups) == 4
    padded = _plan(tree, "padded")
    # the two real buckets merge, the two complex buckets merge — never
    # across the dtype boundary
    assert len(padded.groups) == 2
    dtypes = sorted(str(g.dtype) for g in padded.groups)
    assert dtypes == ["complex64", "float32"]
    for g in padded.groups:
        assert g.batch == 2


def test_tall_and_wide_same_orientation_share_a_bucket():
    """A (16, 6) tall leaf and a (6, 16) wide leaf live on the same
    manifold (orientation key (6, 16)) and land in ONE bucket, the tall
    member marked for transpose — in every grouping mode that buckets."""
    tree = {
        "wide": stiefel.random_stiefel(KEY, (6, 16)),
        "tall": jnp.swapaxes(
            stiefel.random_stiefel(jax.random.PRNGKey(1), (6, 16)), -1, -2
        ),
    }
    for grouping in ("auto", "padded"):
        plan = _plan(tree, grouping)
        assert len(plan.groups) == 1
        (g,) = plan.groups
        assert (g.p, g.n, g.batch) == (6, 16, 2)
        assert sorted(m.transpose for m in g.members) == [False, True]
        assert not g.ragged  # same manifold shape: no padding needed


def test_vector_leaf_error_names_the_leaf_and_shape():
    leaves, treedef = jax.tree.flatten({"v": jnp.ones((4,))})
    with pytest.raises(ValueError, match=r"matrices \(\.\.\., p, n\); leaf 0"):
        plan_groups(leaves, treedef, "auto")
    with pytest.raises(ValueError, match="matrices"):
        plan_groups(leaves, treedef, "padded")


def test_unknown_grouping_rejected():
    leaves, treedef = jax.tree.flatten({"w": jnp.ones((2, 4))})
    with pytest.raises(ValueError, match="grouping"):
        plan_groups(leaves, treedef, "bogus")


# ------------------------------------------------------------- cost model


def test_megagroups_merge_near_shapes_and_split_far_ones():
    """Shapes inside the same aligned tile merge for free; a shape whose
    padding waste exceeds the dispatch overhead stays separate."""
    f32 = jnp.dtype(jnp.float32)
    near = [(8, 60, 64, f32), (8, 64, 64, f32), (4, 50, 64, f32)]
    assert plan_megagroups(near) == [[0, 1, 2]]

    # huge mismatched bucket: padding 4096 small matrices from (4, 64)
    # up to (256, 2048) wastes ~2000x the overhead -> never merges
    far = [(4, 64, 4096, f32), (256, 2048, 64, f32)]
    assert plan_megagroups(far) == [[0], [1]]


def test_megagroups_overhead_knob_controls_merging():
    f32 = jnp.dtype(jnp.float32)
    shapes = [(8, 128, 8, f32), (16, 256, 8, f32)]
    # generous overhead: merging two tiny dispatches wins
    assert plan_megagroups(shapes, DISPATCH_OVERHEAD_BYTES) == [[0, 1]]
    # zero overhead: any padding is a pure loss
    assert plan_megagroups(shapes, 0) == [[0], [1]]


def test_megagroup_partition_is_deterministic_and_dtype_pure():
    shapes = [
        (8, 64, 16, jnp.dtype(jnp.float32)),
        (8, 64, 16, jnp.dtype(jnp.bfloat16)),
        (4, 60, 16, jnp.dtype(jnp.float32)),
        (4, 60, 16, jnp.dtype(jnp.bfloat16)),
    ]
    part = plan_megagroups(shapes)
    assert part == plan_megagroups(shapes)  # deterministic
    for idxs in part:
        assert len({shapes[i][3] for i in idxs}) == 1


def test_aligned_stack_bytes_is_backend_aware(monkeypatch):
    # On TPU the kernel pads to (8, 128) tiles anyway: sub-tile
    # raggedness is free and (4, 60) costs the same as (8, 128).
    monkeypatch.setattr(schedule, "_tile", lambda: (8, 128))
    assert aligned_stack_bytes(4, 60, 2, jnp.float32) == \
        aligned_stack_bytes(8, 128, 2, jnp.float32)
    # The jnp path (CPU/GPU) executes every padded element: true bytes.
    monkeypatch.setattr(schedule, "_tile", lambda: (1, 1))
    assert aligned_stack_bytes(4, 60, 2, jnp.float32) == 2 * 4 * 60 * 4
    assert aligned_stack_bytes(8, 128, 1, jnp.float32) == 8 * 128 * 4


def test_finalized_megagroup_offsets_and_segments_consistent():
    """Members keep flat-leaf order with contiguous offsets; the valid
    segments RLE exactly covers the batch."""
    tree = {
        "a": stiefel.random_stiefel(KEY, (2, 4, 96)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(1), (8, 128)),
        "c": stiefel.random_stiefel(jax.random.PRNGKey(2), (3, 4, 96)),
    }
    plan = _plan(tree, "padded")
    assert len(plan.groups) == 1
    (g,) = plan.groups
    assert [m.leaf for m in g.members] == [0, 1, 2]
    off = 0
    for m in g.members:
        assert m.offset == off
        off += m.count
    assert off == g.batch == 6
    pv, nv = g.valid_shape_arrays()
    np.testing.assert_array_equal(pv, [4, 4, 8, 4, 4, 4])
    np.testing.assert_array_equal(nv, [96, 96, 128, 96, 96, 96])


def test_dispatch_cost_penalizes_tiled_shapes():
    from repro.kernels.ops import FUSED_TRACE_HBM_PASSES

    f32 = jnp.dtype(jnp.float32)
    small = schedule.dispatch_cost_bytes(16, 256, 1, f32, 0)
    huge = schedule.dispatch_cost_bytes(512, 4096, 1, f32, 0)
    # the huge shape blows the whole-kernel VMEM budget -> 15% penalty
    assert huge > FUSED_TRACE_HBM_PASSES * aligned_stack_bytes(512, 4096, 1, f32)
    assert small == FUSED_TRACE_HBM_PASSES * aligned_stack_bytes(16, 256, 1, f32)
