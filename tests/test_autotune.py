"""Autotuned kernel planner: VMEM accounting, candidate choice, cache.

Satellite coverage for ISSUE 3: the corrected whole-kernel VMEM
accounting (the old ``_WHOLE_ARRAYS = 4`` undercounted the live fp32
intermediates), plan-fits assertions, and the autotune cache contract —
a second planner invocation for the same key performs no timing runs,
and the JSON cache file round-trips across processes (simulated with
fresh ``PlanCache`` instances on the same path).
"""

import json

import jax.numpy as jnp
import pytest

from repro.kernels import autotune, ops


@pytest.fixture
def tmp_cache(tmp_path):
    cache = autotune.PlanCache(path=str(tmp_path / "autotune.json"))
    old = autotune._CACHE
    autotune.set_cache(cache)
    yield cache
    autotune.set_cache(old)


# ------------------------------------------------------- VMEM accounting fix


@pytest.mark.parametrize("stages", [
    "pogo", "landing", "ns",
    "fused_pogo+none", "fused_pogo+trace", "fused_pogo+vadam",
    "fused_landing+none", "fused_landing+vadam",
])
@pytest.mark.parametrize("p,n,bsz", [
    (3, 3, 1), (16, 256, 2048), (64, 1024, 16), (256, 2048, 4),
    (128, 4096, 8), (8, 65536, 2),
])
def test_chosen_plan_fits_vmem_budget(stages, p, n, bsz, tmp_cache):
    """Whatever the planner picks, the per-matrix working set computed from
    the actual kernel dataflow times the block size must fit the budget."""
    kind, arg, p_pad, n_pad = ops._plan(p, n, bsz, jnp.float32, stages, True)
    if kind == "whole":
        need = ops.whole_vmem_bytes(p_pad, n_pad, stages) * arg
        assert need <= ops.VMEM_BUDGET_BYTES, (stages, p, n, arg, need)
    else:
        need = ops.tiled_vmem_bytes(p_pad, arg, stages)
        # degenerate huge-p shapes keep a best-effort 128 tile
        assert need <= ops.VMEM_BUDGET_BYTES or arg == 128


def test_old_accounting_bug_shape_now_tiles(tmp_cache):
    """(256, 2048) fp32: the old ``_WHOLE_ARRAYS = 4`` estimate (~9.2 MiB)
    fit the 12 MiB budget, but the kernel's true live set (x, g, ag, bx,
    m, cm, out + 3x(p,p)) is ~15.5 MiB — the planner must tile now."""
    p, n = 256, 2048
    p_pad, n_pad = p, n
    old_estimate = p_pad * n_pad * 4 * 4 + p_pad * p_pad * 4 * 3
    assert old_estimate <= ops.VMEM_BUDGET_BYTES  # the bug's premise
    assert ops.whole_vmem_bytes(p_pad, n_pad, "pogo") > ops.VMEM_BUDGET_BYTES
    kind, *_ = ops._plan(p, n, 4, jnp.float32, "pogo", True)
    assert kind == "tiled"


def test_ns_timer_handles_tiled_candidates():
    """Newton-Schulz has no tiled kernel; its autotune timer must time the
    jnp-reference fallback instead of crashing on block_b=0 candidates
    (p=256, n=8192 makes every whole NS plan blow the VMEM budget)."""
    assert ops.whole_vmem_bytes(256, 8192, "ns") > ops.VMEM_BUDGET_BYTES
    timer = ops._ns_timer(8, 128, jnp.float32, 2, True)
    t = timer({"kind": "tiled", "block_b": 0, "tile_n": 512})
    assert t > 0.0


def test_candidates_heuristic_default_first():
    cands = ops.plan_candidates(16, 256, 2048, "pogo")
    assert cands[0]["kind"] == "whole"
    assert cands[0]["block_b"] == max(c["block_b"] for c in cands)
    # block never exceeds the real batch
    assert ops.plan_candidates(16, 256, 3, "pogo")[0]["block_b"] <= 3


# ------------------------------------------------------------ autotune cache


def _cands():
    return [
        {"kind": "whole", "block_b": 8, "tile_n": 0},
        {"kind": "whole", "block_b": 2, "tile_n": 0},
    ]


def test_second_invocation_performs_no_timing_runs(tmp_cache):
    calls = []

    def timer(cand):
        calls.append(cand["block_b"])
        return 0.1 if cand["block_b"] == 8 else 0.01

    plan1 = autotune.choose("k1", _cands(), timer, enabled=True)
    assert plan1["block_b"] == 2 and plan1["source"] == "autotune"
    n_first = len(calls)
    assert n_first > 0
    plan2 = autotune.choose("k1", _cands(), timer, enabled=True)
    assert len(calls) == n_first, "second invocation must not re-time"
    assert plan2["block_b"] == 2


def test_cache_file_round_trips_across_processes(tmp_cache):
    def timer(cand):
        return 0.01 if cand["block_b"] == 2 else 0.1

    autotune.choose("k2", _cands(), timer, enabled=True)
    # fresh cache object on the same path = a new process
    fresh = autotune.PlanCache(path=tmp_cache.path)
    hit = fresh.lookup("k2")
    assert hit is not None and hit["block_b"] == 2
    # and choose() on the fresh instance performs no timing
    plan = autotune.choose(
        "k2", _cands(),
        lambda c: pytest.fail("timed despite disk cache"),
        cache=fresh, enabled=True,
    )
    assert plan["block_b"] == 2
    payload = json.load(open(tmp_cache.path))
    assert payload["version"] == autotune.PlanCache.VERSION
    assert "k2" in payload["plans"]


def test_stale_cached_plan_is_discarded(tmp_cache):
    tmp_cache.store("k3", {"kind": "whole", "block_b": 999, "tile_n": 0})
    plan = autotune.choose("k3", _cands(), lambda c: 0.01, enabled=True)
    assert plan["block_b"] in (8, 2)


def test_disabled_autotune_takes_heuristic_without_timing(tmp_cache):
    plan = autotune.choose(
        "k4", _cands(), lambda c: pytest.fail("should not time"),
        enabled=False,
    )
    assert plan["block_b"] == 8 and plan["source"] == "heuristic"
    # heuristic choices are NOT persisted to disk
    fresh = autotune.PlanCache(path=tmp_cache.path)
    assert fresh.lookup("k4") is None


def test_heuristic_hit_is_retimed_once_enabled(tmp_cache):
    """A heuristic (untimed) cached plan must not block later autotuning
    in the same process."""
    plan = autotune.choose("k5", _cands(), lambda c: 0.0, enabled=False)
    assert plan["source"] == "heuristic" and plan["block_b"] == 8
    plan = autotune.choose(
        "k5", _cands(),
        lambda c: 0.01 if c["block_b"] == 2 else 0.1,
        enabled=True,
    )
    assert plan["source"] == "autotune" and plan["block_b"] == 2


def test_failing_candidates_are_skipped(tmp_cache):
    """Timing is best-effort: an uncompilable candidate must not abort the
    step trace; if every candidate fails, the heuristic default wins."""

    def flaky(cand):
        if cand["block_b"] == 8:
            raise RuntimeError("mosaic lowering failed")
        return 0.01

    plan = autotune.choose("k6", _cands(), flaky, enabled=True)
    assert plan["block_b"] == 2 and plan["source"] == "autotune"

    def always_fails(cand):
        raise RuntimeError("no candidate works")

    plan = autotune.choose("k7", _cands(), always_fails, enabled=True)
    assert plan["block_b"] == 8 and plan["source"] == "heuristic"


def test_corrupt_cache_file_is_tolerated(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    cache = autotune.PlanCache(path=str(path))
    assert cache.lookup("anything") is None
    cache.store("k", {"kind": "whole", "block_b": 1, "tile_n": 0})
    assert autotune.PlanCache(path=str(path)).lookup("k") is not None


def test_plan_end_to_end_uses_cache(tmp_cache, monkeypatch):
    """ops._plan with a timer + forced autotune: times once, then reuses."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    calls = []

    def timer(cand):
        calls.append(cand)
        return 0.001

    plan_a = ops._plan(16, 256, 64, jnp.float32, "pogo", True, timer)
    n_first = len(calls)
    assert n_first > 0
    plan_b = ops._plan(16, 256, 64, jnp.float32, "pogo", True, timer)
    assert len(calls) == n_first
    assert plan_a == plan_b


# ------------------------------------------- cache-key staleness (ISSUE 4)


def test_plan_key_includes_batch_and_device_kind():
    """Resharded runs must not replay winners tuned at another batch or on
    another chip: the key carries the (local) batch AND the device kind."""
    k = autotune.plan_key(16, 256, 64, "float32", "fused_pogo+trace",
                         backend="cpu", interpret=True)
    assert "b=64," in k
    assert f"device={autotune.device_kind()}" in k
    k_local = autotune.plan_key(16, 256, 8, "float32", "fused_pogo+trace",
                                backend="cpu", interpret=True)
    assert k != k_local  # per-shard local batch is its own key
    k_dev = autotune.plan_key(16, 256, 64, "float32", "fused_pogo+trace",
                              backend="tpu", interpret=False,
                              device="TPU_v4")
    assert "device=TPU_v4" in k_dev


def test_version1_cache_entries_are_invalidated(tmp_path):
    """Pre-ISSUE-4 cache files (version 1: keys on the global B, no device
    kind) must read as empty, not replay wrong winners after a reshard."""
    path = tmp_path / "autotune.json"
    key = "p=16,n=256,b=2048,dtype=float32,stages=pogo,backend=tpu,interp=0"
    path.write_text(json.dumps({
        "version": 1,
        "plans": {key: {"kind": "whole", "block_b": 512, "tile_n": 0,
                        "source": "autotune"}},
    }))
    cache = autotune.PlanCache(path=str(path))
    assert cache.lookup(key) is None
    # the next store rewrites the file at the current version, dropping v1
    cache.store("k_new", {"kind": "whole", "block_b": 2, "tile_n": 0})
    payload = json.load(open(path))
    assert payload["version"] == autotune.PlanCache.VERSION == 2
    assert key not in payload["plans"]
    assert "k_new" in payload["plans"]


# ------------------------------------------------ multi-process merge lock


def test_store_merges_and_releases_lock(tmp_path):
    """Two caches over the same file must both land their keys (the
    lockfile serializes read-merge-replace) and leave no lock behind."""
    path = str(tmp_path / "autotune.json")
    a, b = autotune.PlanCache(path=path), autotune.PlanCache(path=path)
    a.store("k_a", {"kind": "whole", "block_b": 2, "tile_n": 0})
    b.store("k_b", {"kind": "tiled", "block_b": 0, "tile_n": 128})
    payload = json.load(open(path))
    assert set(payload["plans"]) == {"k_a", "k_b"}
    assert not (tmp_path / "autotune.json.lock").exists()


def test_store_retries_on_held_lock_and_counts(tmp_path, monkeypatch):
    """A held lock makes the store back off (counted in STATS) and, once
    every retry is exhausted, fall back to an unlocked merge rather than
    dropping the plan or deadlocking."""
    monkeypatch.setattr(autotune.PlanCache, "LOCK_BACKOFF_S", 1e-4)
    path = str(tmp_path / "autotune.json")
    lock = tmp_path / "autotune.json.lock"
    lock.write_text("")  # someone else holds the lock, forever
    cache = autotune.PlanCache(path=path)
    before = dict(autotune.STATS)
    cache.store("k", {"kind": "whole", "block_b": 1, "tile_n": 0})
    assert (autotune.STATS["merge_retries"] - before["merge_retries"]
            == autotune.PlanCache.LOCK_RETRIES)
    assert (autotune.STATS["merge_lock_failures"]
            - before["merge_lock_failures"] == 1)
    # the plan still landed (best-effort unlocked merge)...
    assert "k" in json.load(open(path))["plans"]
    # ...and the foreign lock was not deleted (it is not provably stale)
    assert lock.exists()


def test_stale_lock_is_broken(tmp_path, monkeypatch):
    """A lockfile whose holder died long ago must not wedge every future
    store: after the retry budget it is unlinked once provably stale."""
    monkeypatch.setattr(autotune.PlanCache, "LOCK_BACKOFF_S", 1e-4)
    monkeypatch.setattr(autotune.PlanCache, "LOCK_STALE_S", 0.0)
    path = str(tmp_path / "autotune.json")
    lock = tmp_path / "autotune.json.lock"
    lock.write_text("")
    cache = autotune.PlanCache(path=path)
    cache.store("k", {"kind": "whole", "block_b": 1, "tile_n": 0})
    assert not lock.exists()  # stale lock broken for the next store
    assert "k" in json.load(open(path))["plans"]
