"""Distributed correctness on 8 fake devices — run in SUBPROCESSES so the
main pytest session keeps its single CPU device (per the assignment, smoke
tests must see 1 device)."""

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, n_devices: int = 8, timeout: int = 900):
    prog = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == {n_devices}
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", prog], env=env, capture_output=True, text=True,
        timeout=timeout,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    return res.stdout


def test_sharded_train_step_matches_single_device():
    """The same smoke train step on a (4, 2) mesh reproduces the 1-device
    loss trajectory — sharding must not change semantics."""
    _run(
        """
        from repro.configs import get_config
        from repro.distributed import shard_hints, sharding
        from repro.launch.mesh import make_test_mesh
        from repro.models import ortho, transformer as tfm
        from repro.train.train_step import TrainConfig, make_train_step

        cfg = get_config("smollm-360m", smoke=True)
        key = jax.random.PRNGKey(0)
        params = ortho.project_init(tfm.init_params(key, cfg), cfg)
        batch = {
            "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        }
        tc = TrainConfig(microbatches=2, warmup_steps=1, decay_steps=10)
        step_fn, optimizer = make_train_step(cfg, tc)
        opt_state = optimizer.init(params)

        # reference: no mesh
        p_ref, o_ref, m_ref = jax.jit(step_fn)(params, opt_state, batch)
        losses_ref = float(m_ref["loss"])

        # sharded
        mesh = make_test_mesh(8)
        shard_hints.set_mesh(mesh)
        step_fn2, optimizer2 = make_train_step(cfg, tc)
        p_sh = sharding.param_shardings(params, mesh)
        params_s = jax.device_put(params, p_sh)
        o_specs = sharding.opt_state_specs(opt_state, params, mesh)
        o_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), o_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        opt_s = jax.device_put(optimizer2.init(params_s), o_sh)
        tok_sh = sharding.token_sharding(mesh, 8)
        batch_s = {k: jax.device_put(v, tok_sh) for k, v in batch.items()}
        with mesh:
            p2, o2, m2 = jax.jit(step_fn2)(params_s, opt_s, batch_s)
        losses_sh = float(m2["loss"])
        print("ref", losses_ref, "sharded", losses_sh)
        assert abs(losses_ref - losses_sh) < 0.05 * (1 + abs(losses_ref))
        # params close too (bf16 tolerance)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=0.05, rtol=0.05)
        print("OK")
        """
    )


def test_tiny_mesh_dryrun_all_archs():
    """Every arch's train entry lowers+compiles on a (2, 2, 2) multi-pod
    test mesh with reduced configs — the mesh-portability contract."""
    _run(
        """
        from repro.configs import ARCHS, get_config
        from repro.distributed import shard_hints, sharding
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tfm
        from repro.train.train_step import TrainConfig, make_train_step

        mesh = make_test_mesh(8, multi_pod=True)
        shard_hints.set_mesh(mesh)
        for arch in sorted(ARCHS):
            cfg = get_config(arch, smoke=True)
            tc = TrainConfig(microbatches=1, warmup_steps=1, decay_steps=10)
            step_fn, optimizer = make_train_step(cfg, tc)
            params_sds = jax.eval_shape(
                lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
            opt_sds = jax.eval_shape(optimizer.init, params_sds)
            p_sh = sharding.param_shardings(params_sds, mesh)
            o_specs = sharding.opt_state_specs(opt_sds, params_sds, mesh)
            def att(tree, sh):
                return jax.tree.map(
                    lambda sd, s: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=s),
                    tree, sh)
            params_in = att(params_sds, p_sh)
            o_sh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), o_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            opt_in = att(opt_sds, o_sh)
            toks = jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=sharding.token_sharding(mesh, 8))
            batch_in = {"tokens": toks, "labels": toks}
            if cfg.frontend and not cfg.encoder_layers:
                batch_in["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (8, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)
            if cfg.encoder_layers:
                if cfg.frontend:
                    batch_in["frontend_embeds"] = jax.ShapeDtypeStruct(
                        (8, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)
                else:
                    batch_in["encoder_tokens"] = toks
            with mesh:
                compiled = jax.jit(step_fn).lower(params_in, opt_in, batch_in).compile()
            assert compiled.cost_analysis() is not None
            print(arch, "ok")
        print("OK")
        """,
        timeout=1800,
    )


def test_compressed_allreduce_error_feedback():
    """int8 EF-psum: mean is exact-ish per step and EF drives long-run
    bias to zero (compressed SGD converges on a quadratic)."""
    _run(
        """
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.compat import shard_map
        from repro.launch.mesh import make_mesh as _make_mesh
        mesh = _make_mesh((8,), ("data",))

        def worker(g, r):
            return compressed_psum(g, "data", r)

        fn = jax.jit(shard_map(worker, mesh=mesh,
            in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
            check_vma=False))

        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (8, 64))  # row i = device i's grad
        r = jnp.zeros_like(g)
        mean, r1 = fn(g, r)
        true_mean = jnp.mean(g, axis=0, keepdims=True)
        # every device's shard of `mean` equals the true mean within int8 step
        err = float(jnp.max(jnp.abs(mean - true_mean)))
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert err < 3 * scale, (err, scale)

        # error feedback: repeated compression of a CONSTANT gradient
        # averages to the true mean (residual carries the rounding)
        acc = jnp.zeros((8, 64)); r = jnp.zeros_like(g)
        for _ in range(64):
            mean, r = fn(g, r)
            acc = acc + mean
        avg = acc / 64
        err2 = float(jnp.max(jnp.abs(avg - true_mean)))
        assert err2 < 0.3 * scale, (err2, scale)
        print("OK")
        """
    )


def test_pipeline_parallel_matches_sequential():
    """GPipe over a 2-stage pod axis == running both stages sequentially."""
    _run(
        """
        from repro.distributed.pipeline import gpipe
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(8, multi_pod=True)  # pod=2
        key = jax.random.PRNGKey(0)
        d = 16
        # stage params: (2, d, d) — one matrix per stage
        w = jax.random.normal(key, (2, d, d)) / d**0.5

        def stage_fn(wi, x):
            return jnp.tanh(x @ wi)

        run = gpipe(stage_fn, mesh)
        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))  # 4 microbatches
        with mesh:
            out = run(w, xs)
        ref = jnp.tanh(jnp.tanh(xs @ w[0]) @ w[1])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("OK")
        """
    )


def test_group_shard_map_replaces_cpu_miscompile():
    """ISSUE-4 regression pin. The old ``shard_hints.group_batch`` hint was
    a silent off-TPU no-op because the CPU host-platform partitioner
    miscompiles a concatenate whose output is consumed batch-sharded
    (WRONG VALUES — even shard-aligned concats). The shard_map schedule
    with the replicated input pin must return exact values on that exact
    repro shape (misaligned 3+5 member concat on the (4, 2) test mesh),
    and the grouped driver must stay fp32-bit-identical to the unsharded
    reference through it."""
    _run(
        """
        from repro import optim
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(8)  # (data=4, model=2)

        # --- the raw repro, routed through the new shard_map path
        a = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (3, 16, 256)))
        b = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (5, 16, 256)))
        shard_hints.set_mesh(mesh)

        def local(x):
            return x @ jnp.swapaxes(x, -1, -2)

        wrapped = shard_hints.shard_group_step(local, 8, 3, pin_inputs=True)
        assert wrapped is not None
        out = jax.jit(lambda a, b: wrapped(jnp.concatenate([a, b], 0)))(a, b)
        x_np = np.concatenate([a, b], 0)
        assert np.array_equal(np.asarray(out), x_np @ np.swapaxes(x_np, -1, -2)), \\
            "shard_map group path returned wrong values on the concat repro"
        shard_hints.set_mesh(None)

        # --- the driver end to end: misaligned multi-member group
        x = stiefel.random_stiefel(jax.random.PRNGKey(0), (8, 16, 256))
        g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (8, 16, 256))
        params = {"a": np.asarray(x[:3]), "b": np.asarray(x[3:])}
        grads = {"a": np.asarray(g[:3]), "b": np.asarray(g[3:])}

        def run(mesh, method, mode="2d", **kw):
            if mesh is None:
                shard_hints.set_mesh(None)
            else:
                shard_hints.set_mesh(mesh, mode)
            try:
                opt = api.orthogonal(
                    method, learning_rate=0.1,
                    base_optimizer=optim.chain(optim.trace(0.3)), **kw)
                s = opt.init(params)
                u, s2 = jax.jit(opt.update)(grads, s, params)
                return (jax.tree.map(np.asarray, u),
                        np.asarray(s2.last_distance.per_group[0]))
            finally:
                shard_hints.set_mesh(None)

        # DP bit-identity through the gathered concat. Non-fused methods
        # never route to TP, so the default "2d" mode shards batch over
        # data=4 exactly as at PR 4; the fused pogo step WOULD claim the
        # model axis for TP in "2d", so its bit-identity pin runs in "dp"
        # mode (all 8 devices to the batch — per-matrix math still never
        # crosses shards).
        for method, mode, kw in (
                ("pogo", "2d", {}),
                ("pogo", "dp", {"use_kernel": True}),
                ("landing", "2d", {"safe_step": False}),
                ("rsdm", "2d", {})):
            u_ref, d_ref = run(None, method, **kw)
            u_sh, d_sh = run(mesh, method, mode=mode, **kw)
            for lr, ls in zip(jax.tree.leaves(u_ref), jax.tree.leaves(u_sh)):
                assert np.array_equal(lr, ls), (method, kw)
            assert np.array_equal(d_ref, d_sh), (method, kw)
            print(method, kw, "bit-identical")

        # In the default "2d" mode the model axis now belongs to the TP
        # group schedule, whose chunked grams differ from the literal
        # single-device gram by O(eps) (parity vs the chunked oracle is
        # pinned in the TP tests). Here pin only that the gathered-group
        # TP route returns sane values on the miscompile repro shape — the
        # CPU partitioner bug produced garbage, not ulp drift.
        u_ref, d_ref = run(None, "pogo", use_kernel=True)
        u_tp, d_tp = run(mesh, "pogo", use_kernel=True)
        for lr, ls in zip(jax.tree.leaves(u_ref), jax.tree.leaves(u_tp)):
            assert np.allclose(lr, ls, atol=1e-6), "TP gathered-group route"
        assert np.allclose(d_ref, d_tp, atol=1e-5), "TP telemetry"
        print("OK")
        """
    )


def test_sharded_fused_step_bit_identical_and_planner_local():
    """The sharded fused group step on an 8-device data mesh is fp32
    bit-identical per matrix to the single-device path (matrices are
    independent; shard_map only changes which device holds which slice),
    and the kernel planner keys on the PER-SHARD local batch."""
    _run(
        """
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.kernels import autotune
        from repro.launch.mesh import make_mesh

        # Isolate the plan cache: the negative "no b=64 key" assertion
        # below must not see keys merged from the developer's real
        # ~/.cache autotune file.
        autotune.set_cache(autotune.PlanCache(
            path=os.path.join(tempfile.mkdtemp(), "autotune.json")))

        B, p, n = 64, 16, 256
        x = stiefel.random_stiefel(jax.random.PRNGKey(0), (B, p, n))
        g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, p, n))
        cs = api.ConstraintSet.from_tree({"w": np.asarray(x)})
        gs = api.ConstraintSet.from_tree({"w": np.asarray(g)})

        def run(mesh, use_kernel=True):
            shard_hints.set_mesh(mesh)
            try:
                opt = api.orthogonal(
                    "pogo", learning_rate=0.1, use_kernel=use_kernel,
                    base_optimizer=optim.chain(optim.trace(0.3)))
                if mesh is not None:
                    sh = NamedSharding(mesh, P("data", None, None))
                    ps = api.ConstraintSet(
                        cs.plan, tuple(jax.device_put(s, sh) for s in cs.stacks))
                    gg = api.ConstraintSet(
                        gs.plan, tuple(jax.device_put(s, sh) for s in gs.stacks))
                else:
                    ps, gg = cs, gs
                s = opt.init(ps)
                u, s2 = jax.jit(opt.update)(gg, s, ps)
                return np.asarray(u.stacks[0]), np.asarray(
                    s2.last_distance.per_group[0])
            finally:
                shard_hints.set_mesh(None)

        mesh = make_mesh((8,), ("data",))
        u_ref, d_ref = run(None)
        u_sh, d_sh = run(mesh)
        assert np.array_equal(u_ref, u_sh), "fused sharded step diverged"
        assert np.array_equal(d_ref, d_sh), "sharded telemetry diverged"

        # Per-shard planning: the landing kernel path consults the planner
        # inside shard_map, so the cache key must carry B_local = 64/8.
        shard_hints.set_mesh(mesh)
        sh = NamedSharding(mesh, P("data", None, None))
        ps = api.ConstraintSet(
            cs.plan, tuple(jax.device_put(s, sh) for s in cs.stacks))
        gg = api.ConstraintSet(
            gs.plan, tuple(jax.device_put(s, sh) for s in gs.stacks))
        opt2 = api.orthogonal("landing", learning_rate=0.1, use_kernel=True)
        s = opt2.init(ps)
        jax.jit(opt2.update)(gg, s, ps)
        keys = list(autotune.get_cache()._mem)
        assert any("b=8," in k and "stages=landing" in k for k in keys), keys
        assert not any("b=64," in k for k in keys), keys
        shard_hints.set_mesh(None)
        print("OK")
        """
    )


def test_padded_megagroup_sharded_bit_identical():
    """ISSUE-5 acceptance (8-device leg): grouping="padded" under the
    shard_map group schedule — ragged (B,) mask arrays partition with the
    stack — stays fp32 bit-identical to the unsharded padded path, and
    per-matrix-close to per_leaf, for the two-stage AND fused paths."""
    _run(
        """
        from repro import optim
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))

        # heterogeneous shapes whose padded megagroup batch (8) divides
        # the 8-device data axis
        def make(seed, shape):
            return np.asarray(stiefel.random_stiefel(
                jax.random.PRNGKey(seed), shape))
        params = {"a": make(0, (4, 8, 128)), "b": make(1, (3, 4, 96)),
                  "d": make(2, (8, 120))}
        grads = jax.tree.map(
            lambda p: np.asarray(0.1 * jax.random.normal(
                jax.random.PRNGKey(9), p.shape), np.float32), params)

        def run(mesh, grouping, **kw):
            shard_hints.set_mesh(mesh)
            try:
                opt = api.orthogonal(
                    "pogo", learning_rate=0.1, grouping=grouping,
                    base_optimizer=optim.chain(optim.trace(0.3)), **kw)
                s = opt.init(params)
                u, s2 = jax.jit(opt.update)(grads, s, params)
                return (jax.tree.map(np.asarray, u),
                        [np.asarray(d) for d in s2.last_distance.per_group])
            finally:
                shard_hints.set_mesh(None)

        for kw in ({}, {"use_kernel": True}):
            u_ref, d_ref = run(None, "padded", **kw)
            u_sh, d_sh = run(mesh, "padded", **kw)
            for lr, ls in zip(jax.tree.leaves(u_ref), jax.tree.leaves(u_sh)):
                assert np.array_equal(lr, ls), kw
            for dr, ds in zip(d_ref, d_sh):
                assert np.array_equal(dr, ds), kw
            # and padded == per_leaf per matrix (fp32 tolerance)
            u_pl, _ = run(mesh, "per_leaf", **kw)
            for lr, ls in zip(jax.tree.leaves(u_pl), jax.tree.leaves(u_sh)):
                np.testing.assert_allclose(lr, ls, atol=5e-6, rtol=1e-5)
            print("padded sharded", kw, "bit-identical")
        print("OK")
        """
    )


def test_constraint_step_donates_buffers_no_param_copy():
    """The lowered resting-state step aliases (donates) the param stacks
    and moment buffers input->output, and the optimized HLO contains no
    param-stack-sized copy — the sharded step rewrites X in place."""
    _run(
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        B, p, n = 64, 16, 256
        mesh = make_mesh((8,), ("data",))
        shard_hints.set_mesh(mesh)
        x = stiefel.random_stiefel(jax.random.PRNGKey(0), (B, p, n))
        g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, p, n))
        sh = NamedSharding(mesh, P("data", None, None))
        cs0 = api.ConstraintSet.from_tree({"w": np.asarray(x)})
        gs0 = api.ConstraintSet.from_tree({"w": np.asarray(g)})
        params = api.ConstraintSet(
            cs0.plan, tuple(jax.device_put(s, sh) for s in cs0.stacks))
        grads = api.ConstraintSet(
            gs0.plan, tuple(jax.device_put(s, sh) for s in gs0.stacks))
        opt = api.orthogonal(
            "pogo", learning_rate=0.1, use_kernel=True,
            base_optimizer=optim.chain(optim.trace(0.3)))
        state = opt.init(params)
        step = api.constraint_step(opt)
        txt = step.lower(params, state, grads).compile().as_text()
        assert "input_output_alias" in txt, "no donation in lowered step"
        # No copy of the param stack, neither global (64,...) nor the
        # per-device local shard (8,...): donation means in-place rewrite.
        # Same scan the DonationAliased analysis rule runs in CI.
        from repro.analysis.lowering import find_copies_of, hlo_shape_str
        shapes = [
            hlo_shape_str(jax.ShapeDtypeStruct((B, p, n), np.float32)),
            hlo_shape_str(jax.ShapeDtypeStruct((B // 8, p, n), np.float32)),
        ]
        bad = find_copies_of(txt, shapes)
        assert not bad, bad
        # and the step actually runs with donated inputs
        p2, s2, health = step(params, state, grads)
        assert p2.stacks[0].sharding.spec == P("data", None, None)
        assert bool(health.finite)
        shard_hints.set_mesh(None)
        print("OK")
        """
    )


def test_checkpoint_sharded_restore_smaller_mesh(tmp_path):
    """Sharded OrthoState/GroupedDistances written on an 8-device mesh
    restore bit-exactly onto a 4-device mesh (elastic resharding), with
    the restored leaves placed batch-sharded on the new mesh."""
    ckpt_dir = str(tmp_path / "ckpt")
    save_body = f"""
        import hashlib, json, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.checkpoint import checkpoint as ckpt
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        DIR = {ckpt_dir!r}
        B, p, n = 64, 16, 256
        mesh = make_mesh((8,), ("data",))
        shard_hints.set_mesh(mesh)
        x = stiefel.random_stiefel(jax.random.PRNGKey(0), (B, p, n))
        g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, p, n))
        sh = NamedSharding(mesh, P("data", None, None))
        cs0 = api.ConstraintSet.from_tree({{"w": np.asarray(x)}})
        gs0 = api.ConstraintSet.from_tree({{"w": np.asarray(g)}})
        params = api.ConstraintSet(
            cs0.plan, tuple(jax.device_put(s, sh) for s in cs0.stacks))
        grads = api.ConstraintSet(
            gs0.plan, tuple(jax.device_put(s, sh) for s in gs0.stacks))
        opt = api.orthogonal(
            "pogo", learning_rate=0.1, use_kernel=True,
            base_optimizer=optim.chain(optim.trace(0.3)))
        state = opt.init(params)
        step = api.constraint_step(opt)
        params, state, _h = step(params, state, grads)  # sharded dists + moments
        assert state.last_distance.per_group[0].sharding.spec == P("data")
        ckpt.save(DIR, 7, (params, state))
        digests = [hashlib.md5(np.asarray(l).tobytes()).hexdigest()
                   for l in jax.tree.leaves((params, state))]
        with open(os.path.join(DIR, "digests.json"), "w") as f:
            json.dump(digests, f)
        print("OK")
    """
    restore_body = f"""
        import hashlib, json, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.checkpoint import checkpoint as ckpt
        from repro.core import api, stiefel
        from repro.launch.mesh import make_mesh

        DIR = {ckpt_dir!r}
        B, p, n = 64, 16, 256
        mesh = make_mesh((4,), ("data",))
        cs_like = api.ConstraintSet.from_tree(
            {{"w": np.zeros((B, p, n), np.float32)}})
        opt = api.orthogonal(
            "pogo", learning_rate=0.1, use_kernel=True,
            base_optimizer=optim.chain(optim.trace(0.3)))
        like = (cs_like, opt.init(cs_like))

        def shard_for(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == B:
                return NamedSharding(
                    mesh, P("data", *([None] * (leaf.ndim - 1))))
            return NamedSharding(mesh, P())

        shardings = jax.tree.map(shard_for, like)
        step, restored = ckpt.restore_latest(DIR, like, shardings=shardings)
        assert step == 7
        with open(os.path.join(DIR, "digests.json")) as f:
            digests = json.load(f)
        leaves = jax.tree.leaves(restored)
        assert len(leaves) == len(digests)
        for leaf, d in zip(leaves, digests):
            assert hashlib.md5(np.asarray(leaf).tobytes()).hexdigest() == d
        rp, rs = restored
        assert rp.stacks[0].sharding.spec == P("data", None, None)
        assert len(rp.stacks[0].sharding.mesh.devices) == 4
        assert rs.last_distance.per_group[0].sharding.spec == P("data")
        print("OK")
    """
    _run(save_body, n_devices=8)
    _run(restore_body, n_devices=4)


def test_batch_spec_divisibility_fallback():
    _run(
        """
        from repro.distributed import sharding
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(8, multi_pod=True)  # pod=2, data=2, model=2
        # batch 1: cannot shard -> replicated
        assert sharding.batch_spec(mesh, 1) == jax.sharding.PartitionSpec(None)
        # batch 2: only pod divides
        s2 = sharding.batch_spec(mesh, 2)
        # batch 4: pod x data
        s4 = sharding.batch_spec(mesh, 4)
        print("s2", s2, "s4", s4)
        assert s4[0] == ("pod", "data")
        print("OK")
        """
    )


def test_sharded_resume_bit_identical(tmp_path):
    """Resume determinism on the 8-device mesh: save the sharded
    (ConstraintSet, OrthoState) at step 4, restore into fresh
    batch-sharded objects, run 4 more steps — params and the
    GroupedDistances telemetry must be bit-identical to the
    uninterrupted 8-step run (the divergence-rollback policy depends on
    exact replay)."""
    ckpt_dir = str(tmp_path / "ckpt")
    _run(
        f"""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.checkpoint import checkpoint as ckpt
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        DIR = {ckpt_dir!r}
        B, p, n = 32, 8, 64
        mesh = make_mesh((8,), ("data",))
        shard_hints.set_mesh(mesh)
        sh = NamedSharding(mesh, P("data", None, None))

        def fresh():
            x = stiefel.random_stiefel(jax.random.PRNGKey(0), (B, p, n))
            g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, p, n))
            cs0 = api.ConstraintSet.from_tree({{"w": np.asarray(x)}})
            gs0 = api.ConstraintSet.from_tree({{"w": np.asarray(g)}})
            params = api.ConstraintSet(
                cs0.plan, tuple(jax.device_put(s, sh) for s in cs0.stacks))
            grads = api.ConstraintSet(
                gs0.plan, tuple(jax.device_put(s, sh) for s in gs0.stacks))
            opt = api.orthogonal(
                "pogo", learning_rate=0.1,
                base_optimizer=optim.chain(optim.trace(0.3)))
            return opt, api.constraint_step(opt), params, grads

        opt, step, params, grads = fresh()
        state = opt.init(params)
        for _ in range(8):
            params, state, _h = step(params, state, grads)
        full = [np.asarray(l) for l in jax.tree.leaves((params, state))]

        opt, step, params, grads = fresh()
        state = opt.init(params)
        for _ in range(4):
            params, state, _h = step(params, state, grads)
        ckpt.save(DIR, 4, (params, state))

        opt, step, params, grads = fresh()
        like = (params, opt.init(params))

        def shard_for(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == B:
                return NamedSharding(
                    mesh, P("data", *([None] * (leaf.ndim - 1))))
            return NamedSharding(mesh, P())

        got_step, restored = ckpt.restore_latest(
            DIR, like, shardings=jax.tree.map(shard_for, like))
        assert got_step == 4
        params, state = restored
        for _ in range(4):
            params, state, _h = step(params, state, grads)
        resumed = [np.asarray(l) for l in jax.tree.leaves((params, state))]

        assert len(full) == len(resumed)
        for a, b in zip(full, resumed):
            np.testing.assert_array_equal(a, b)
        assert state.last_distance.per_group[0].sharding.spec == P("data")
        print("OK")
        """
    )


def test_tp_group_step_one_psum_parity_donation():
    """ISSUE-10 acceptance: a (B=8, p=64, n=16384) fp32 group step on a
    pure-TP model=8 mesh partitions n so no device ever materializes a
    full matrix, lowers to EXACTLY ONE collective (the flat gram-payload
    all-reduce, 3*B*p^2 fp32), donates the n-sharded param stack in
    place, stays per-matrix fp32 bit-identical to the single-device
    TP-schedule oracle (``kops.fused_group_step_tp``), and the kernel
    planner keys on the LOCAL n shard, never the global n."""
    _run(
        """
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import optim
        from repro.analysis.lowering import (
            find_copies_of, hlo_shape_str, parse_collectives)
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.kernels import autotune
        from repro.kernels import ops as kops
        from repro.launch.mesh import make_mesh
        from repro.optim import fused as optim_fused

        autotune.set_cache(autotune.PlanCache(
            path=os.path.join(tempfile.mkdtemp(), "autotune.json")))

        B, p, n = 8, 64, 16384
        mesh = make_mesh((8,), ("model",))
        shard_hints.set_mesh(mesh)  # "2d": batch replicated, n over model
        x = stiefel.random_stiefel(jax.random.PRNGKey(0), (B, p, n))
        g = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, p, n))
        sh = NamedSharding(mesh, P(None, None, "model"))
        cs0 = api.ConstraintSet.from_tree({"w": np.asarray(x, np.float32)})
        gs0 = api.ConstraintSet.from_tree({"w": np.asarray(g, np.float32)})
        params = api.ConstraintSet(
            cs0.plan, tuple(jax.device_put(s, sh) for s in cs0.stacks))
        grads = api.ConstraintSet(
            gs0.plan, tuple(jax.device_put(s, sh) for s in gs0.stacks))
        # no device holds more than the (B, p, n/8) local block
        assert params.stacks[0].sharding.shard_shape(
            (B, p, n)) == (B, p, n // 8)

        base = optim.chain(optim.trace(0.3))
        opt = api.orthogonal("pogo", learning_rate=0.1, use_kernel=True,
                             base_optimizer=base)
        state = opt.init(params)

        # --- exactly one TP collective in the lowered update
        txt = jax.jit(opt.update).lower(
            grads, state, params).compile().as_text()
        colls = parse_collectives(txt)
        counts = {k: v["count"] for k, v in colls.items() if v["count"]}
        assert counts == {"all-reduce": 1}, counts
        op = colls["all-reduce"]["ops"][0]
        assert op["group"] == 8, op
        # flat (B, 3*p*p) fp32 gram payload — never the matrix itself
        assert op["bytes"] == B * 3 * p * p * 4, op

        # --- donation: in-place rewrite, no stack-sized copy (global OR
        # the per-device (B, p, n/8) local block)
        step = api.constraint_step(opt)
        stxt = step.lower(params, state, grads).compile().as_text()
        assert "input_output_alias" in stxt, "no donation in TP step"
        shapes = [
            hlo_shape_str(jax.ShapeDtypeStruct((B, p, n), np.float32)),
            hlo_shape_str(jax.ShapeDtypeStruct((B, p, n // 8), np.float32)),
        ]
        bad = find_copies_of(stxt, shapes)
        assert not bad, bad

        # --- fp32 bit-parity vs the single-device TP-schedule oracle
        # (chunked left-fold partial-gram sum == psum contribution order;
        # the oracle step is jitted as ONE graph, like the driver — at
        # p=64 eager per-op compilation drifts by an ulp)
        fb = optim_fused.resolve_fused_base(base)
        upd = jax.jit(opt.update)
        ps, s = params, state
        dists = []
        for _ in range(2):
            u, s = upd(grads, s, ps)
            ps = ps.apply(u)
            dists.append(np.asarray(s.last_distance.per_group[0]))

        # --- planner keys carry the LOCAL n shard, never the global n
        # (checked BEFORE the single-device oracle below, whose own
        # full-width dispatches legitimately key on n=16384)
        keys = list(autotune.get_cache()._mem)
        assert any("n=2048," in k for k in keys), keys
        assert not any("n=16384," in k for k in keys), keys

        @jax.jit
        def oracle(xo, go, mu):
            x2, mu2, _, dist, _ = kops.fused_group_step_tp(
                xo, go, jnp.float32(0.1), method="pogo", lam=0.5,
                base_kind=fb.kind, hyper=fb.hyper,
                post_scale=fb.post_scale, mu=mu, tp_shards=8)
            ug = (x2 - xo).astype(xo.dtype)
            return xo + ug, mu2, dist

        xo = jnp.asarray(np.asarray(x), jnp.float32)
        go = jnp.asarray(np.asarray(g), jnp.float32)
        mu = jnp.zeros_like(xo)
        odists = []
        for _ in range(2):
            xo, mu, dist = oracle(xo, go, mu)
            odists.append(np.asarray(dist))
        assert np.array_equal(np.asarray(ps.stacks[0]), np.asarray(xo))
        mu_drv = np.asarray(jax.tree.leaves(s.base_state)[0])
        assert np.array_equal(mu_drv, np.asarray(mu))
        for d1, d2 in zip(dists, odists):
            assert np.array_equal(d1, d2)

        # --- and the donated step actually runs sharded + healthy
        p2, s2, health = step(params, state, grads)
        assert p2.stacks[0].sharding.spec == P(None, None, "model")
        assert bool(health.finite)
        shard_hints.set_mesh(None)
        print("OK")
        """
    )


def test_tp_compressed_psum_error_feedback_bounded():
    """tp_compress=True (int8-quantized gram-payload psum with error
    feedback, DESIGN.md §Tensor-parallel execution): long-run feasibility
    stays BOUNDED at the int8 quantization floor — finite, plateaued, no
    secular growth — with the EF residual carried shard-major in
    ``OrthoState.extras``. The exact-psum run on the same DPxTP mesh
    reaches a floor orders of magnitude tighter (the compressed floor
    ~ max|payload|/127 is inherent to the wire format, not drift)."""
    _run(
        """
        from repro import optim
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.kernels import ref as kref
        from repro.launch.mesh import make_test_mesh

        B, p, n = 8, 16, 256
        params = {"w": np.asarray(stiefel.random_stiefel(
            jax.random.PRNGKey(0), (B, p, n)), np.float32)}
        grads = {"w": np.asarray(0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (B, p, n)), np.float32)}
        mesh = make_test_mesh(8)  # data=4, model=2 -> DP x TP
        shard_hints.set_mesh(mesh, "2d")
        base = optim.chain(optim.trace(0.3))

        def run(tp_compress, steps):
            opt = api.orthogonal("pogo", learning_rate=0.1,
                                 use_kernel=True, base_optimizer=base,
                                 tp_compress=tp_compress)
            s = opt.init(params)
            ps = params
            upd = jax.jit(opt.update)
            trace = []
            for _ in range(steps):
                u, s = upd(grads, s, ps)
                ps = optim.apply_updates(ps, u)
                trace.append(float(api.max_distance(s)))
            return np.asarray(trace), s

        exact, _ = run(False, 40)
        comp, sc = run(True, 40)
        # exact psum: same floor as the single-device fused step
        assert exact[-1] < 1e-3, exact[-1]
        # EF state carried shard-major (tp_width, B, K) across steps
        assert isinstance(sc.extras, api.TpEfState), type(sc.extras)
        ef = np.asarray(sc.extras.residuals[0])
        K = kref.tp_payload_width(p, "trace")
        assert ef.shape == (2, B, K) and ef.dtype == np.float32, ef.shape
        # bounded at the quantization floor, no secular growth
        assert np.all(np.isfinite(comp)), comp
        assert comp.max() < 0.1, comp.max()
        early, late = comp[10:20].mean(), comp[30:40].mean()
        assert late <= 2.0 * early + 1e-3, (early, late)
        assert exact[-1] < comp[-1]
        shard_hints.set_mesh(None)
        print("exact", exact[-1], "compressed", comp[-1])
        print("OK")
        """
    )


def test_checkpoint_tp_restore_different_width(tmp_path):
    """A TP-compressed OrthoState saved at TP=8 restores onto a
    (2, 4) DPxTP mesh bit-exactly for every math leaf; the
    ``TpEfState`` error-feedback residual — whose leading dim IS the TP
    width — is re-armed to zeros at the new width with a RuntimeWarning
    (mirrors the PR-4 elastic DP resharding test)."""
    ckpt_dir = str(tmp_path / "ckpt")
    save_body = f"""
        import hashlib, json, os
        from repro import optim
        from repro.checkpoint import checkpoint as ckpt
        from repro.core import api, stiefel
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        DIR = {ckpt_dir!r}
        B, p, n = 8, 16, 256
        mesh = make_mesh((8,), ("model",))
        shard_hints.set_mesh(mesh)
        params = {{"w": np.asarray(stiefel.random_stiefel(
            jax.random.PRNGKey(0), (B, p, n)), np.float32)}}
        grads = {{"w": np.asarray(0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (B, p, n)), np.float32)}}
        opt = api.orthogonal("pogo", learning_rate=0.1, use_kernel=True,
                             base_optimizer=optim.chain(optim.trace(0.3)),
                             tp_compress=True)
        s = opt.init(params)
        for _ in range(3):
            u, s = jax.jit(opt.update)(grads, s, params)
            params = optim.apply_updates(params, u)
        assert isinstance(s.extras, api.TpEfState)
        assert s.extras.residuals[0].shape[0] == 8  # saved at TP width 8
        ckpt.save(DIR, 7, (params, s))
        meta = [
            [list(np.asarray(l).shape),
             hashlib.md5(np.asarray(l).tobytes()).hexdigest()]
            for l in jax.tree.leaves((params, s))]
        with open(os.path.join(DIR, "digests.json"), "w") as f:
            json.dump(meta, f)
        print("OK")
    """
    restore_body = f"""
        import hashlib, json, os, warnings
        from repro import optim
        from repro.checkpoint import checkpoint as ckpt
        from repro.core import api
        from repro.distributed import shard_hints
        from repro.launch.mesh import make_mesh

        DIR = {ckpt_dir!r}
        B, p, n = 8, 16, 256
        mesh = make_mesh((2, 4), ("data", "model"))
        shard_hints.set_mesh(mesh, "2d")
        params = {{"w": np.zeros((B, p, n), np.float32)}}
        grads = {{"w": np.zeros((B, p, n), np.float32)}}
        opt = api.orthogonal("pogo", learning_rate=0.1, use_kernel=True,
                             base_optimizer=optim.chain(optim.trace(0.3)),
                             tp_compress=True)
        s = opt.init(params)
        # one step materializes the width-4 TpEfState in the like tree
        _u, s = jax.jit(opt.update)(grads, s, params)
        assert isinstance(s.extras, api.TpEfState)
        assert s.extras.residuals[0].shape[0] == 4
        like = (params, s)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            step, restored = ckpt.restore_latest(DIR, like)
        assert step == 7
        assert any(issubclass(w.category, RuntimeWarning)
                   and "error-feedback" in str(w.message) for w in wlog), (
            [str(w.message) for w in wlog])
        with open(os.path.join(DIR, "digests.json")) as f:
            meta = json.load(f)
        leaves = jax.tree.leaves(restored)
        assert len(leaves) == len(meta)
        reset = 0
        for leaf, (shape, digest) in zip(leaves, meta):
            a = np.asarray(leaf)
            if list(a.shape) == shape:
                assert hashlib.md5(a.tobytes()).hexdigest() == digest
            else:
                # the EF leaf: re-armed at the new TP width, all zeros
                assert a.shape == (4, B, 3 * p * p), a.shape
                assert not a.any()
                reset += 1
        assert reset == 1, reset
        shard_hints.set_mesh(None)
        print("OK")
    """
    _run(save_body, n_devices=8)
    _run(restore_body, n_devices=8)
