"""Distributed correctness on 8 fake devices — run in SUBPROCESSES so the
main pytest session keeps its single CPU device (per the assignment, smoke
tests must see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, n_devices: int = 8, timeout: int = 900):
    prog = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == {n_devices}
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", prog], env=env, capture_output=True, text=True,
        timeout=timeout,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    return res.stdout


def test_sharded_train_step_matches_single_device():
    """The same smoke train step on a (4, 2) mesh reproduces the 1-device
    loss trajectory — sharding must not change semantics."""
    _run(
        """
        from repro.configs import get_config
        from repro.distributed import shard_hints, sharding
        from repro.launch.mesh import make_test_mesh
        from repro.models import ortho, transformer as tfm
        from repro.train.train_step import TrainConfig, make_train_step

        cfg = get_config("smollm-360m", smoke=True)
        key = jax.random.PRNGKey(0)
        params = ortho.project_init(tfm.init_params(key, cfg), cfg)
        batch = {
            "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        }
        tc = TrainConfig(microbatches=2, warmup_steps=1, decay_steps=10)
        step_fn, optimizer = make_train_step(cfg, tc)
        opt_state = optimizer.init(params)

        # reference: no mesh
        p_ref, o_ref, m_ref = jax.jit(step_fn)(params, opt_state, batch)
        losses_ref = float(m_ref["loss"])

        # sharded
        mesh = make_test_mesh(8)
        shard_hints.set_mesh(mesh)
        step_fn2, optimizer2 = make_train_step(cfg, tc)
        p_sh = sharding.param_shardings(params, mesh)
        params_s = jax.device_put(params, p_sh)
        o_specs = sharding.opt_state_specs(opt_state, params, mesh)
        o_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), o_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        opt_s = jax.device_put(optimizer2.init(params_s), o_sh)
        tok_sh = sharding.token_sharding(mesh, 8)
        batch_s = {k: jax.device_put(v, tok_sh) for k, v in batch.items()}
        with mesh:
            p2, o2, m2 = jax.jit(step_fn2)(params_s, opt_s, batch_s)
        losses_sh = float(m2["loss"])
        print("ref", losses_ref, "sharded", losses_sh)
        assert abs(losses_ref - losses_sh) < 0.05 * (1 + abs(losses_ref))
        # params close too (bf16 tolerance)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=0.05, rtol=0.05)
        print("OK")
        """
    )


def test_tiny_mesh_dryrun_all_archs():
    """Every arch's train entry lowers+compiles on a (2, 2, 2) multi-pod
    test mesh with reduced configs — the mesh-portability contract."""
    _run(
        """
        from repro.configs import ARCHS, get_config
        from repro.distributed import shard_hints, sharding
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tfm
        from repro.train.train_step import TrainConfig, make_train_step

        mesh = make_test_mesh(8, multi_pod=True)
        shard_hints.set_mesh(mesh)
        for arch in sorted(ARCHS):
            cfg = get_config(arch, smoke=True)
            tc = TrainConfig(microbatches=1, warmup_steps=1, decay_steps=10)
            step_fn, optimizer = make_train_step(cfg, tc)
            params_sds = jax.eval_shape(
                lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
            opt_sds = jax.eval_shape(optimizer.init, params_sds)
            p_sh = sharding.param_shardings(params_sds, mesh)
            o_specs = sharding.opt_state_specs(opt_sds, params_sds, mesh)
            def att(tree, sh):
                return jax.tree.map(
                    lambda sd, s: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=s),
                    tree, sh)
            params_in = att(params_sds, p_sh)
            o_sh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), o_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            opt_in = att(opt_sds, o_sh)
            toks = jax.ShapeDtypeStruct((8, 32), jnp.int32,
                sharding=sharding.token_sharding(mesh, 8))
            batch_in = {"tokens": toks, "labels": toks}
            if cfg.frontend and not cfg.encoder_layers:
                batch_in["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (8, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)
            if cfg.encoder_layers:
                if cfg.frontend:
                    batch_in["frontend_embeds"] = jax.ShapeDtypeStruct(
                        (8, cfg.num_frontend_tokens, cfg.d_model), cfg.dtype)
                else:
                    batch_in["encoder_tokens"] = toks
            with mesh:
                compiled = jax.jit(step_fn).lower(params_in, opt_in, batch_in).compile()
            assert compiled.cost_analysis() is not None
            print(arch, "ok")
        print("OK")
        """,
        timeout=1800,
    )


def test_compressed_allreduce_error_feedback():
    """int8 EF-psum: mean is exact-ish per step and EF drives long-run
    bias to zero (compressed SGD converges on a quadratic)."""
    _run(
        """
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.compat import shard_map
        from repro.launch.mesh import make_mesh as _make_mesh
        mesh = _make_mesh((8,), ("data",))

        def worker(g, r):
            return compressed_psum(g, "data", r)

        fn = jax.jit(shard_map(worker, mesh=mesh,
            in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
            check_vma=False))

        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (8, 64))  # row i = device i's grad
        r = jnp.zeros_like(g)
        mean, r1 = fn(g, r)
        true_mean = jnp.mean(g, axis=0, keepdims=True)
        # every device's shard of `mean` equals the true mean within int8 step
        err = float(jnp.max(jnp.abs(mean - true_mean)))
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert err < 3 * scale, (err, scale)

        # error feedback: repeated compression of a CONSTANT gradient
        # averages to the true mean (residual carries the rounding)
        acc = jnp.zeros((8, 64)); r = jnp.zeros_like(g)
        for _ in range(64):
            mean, r = fn(g, r)
            acc = acc + mean
        avg = acc / 64
        err2 = float(jnp.max(jnp.abs(avg - true_mean)))
        assert err2 < 0.3 * scale, (err2, scale)
        print("OK")
        """
    )


def test_pipeline_parallel_matches_sequential():
    """GPipe over a 2-stage pod axis == running both stages sequentially."""
    _run(
        """
        from repro.distributed.pipeline import gpipe
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(8, multi_pod=True)  # pod=2
        key = jax.random.PRNGKey(0)
        d = 16
        # stage params: (2, d, d) — one matrix per stage
        w = jax.random.normal(key, (2, d, d)) / d**0.5

        def stage_fn(wi, x):
            return jnp.tanh(x @ wi)

        run = gpipe(stage_fn, mesh)
        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))  # 4 microbatches
        with mesh:
            out = run(w, xs)
        ref = jnp.tanh(jnp.tanh(xs @ w[0]) @ w[1])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("OK")
        """
    )


def test_batch_spec_divisibility_fallback():
    _run(
        """
        from repro.distributed import sharding
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(8, multi_pod=True)  # pod=2, data=2, model=2
        # batch 1: cannot shard -> replicated
        assert sharding.batch_spec(mesh, 1) == jax.sharding.PartitionSpec(None)
        # batch 2: only pod divides
        s2 = sharding.batch_spec(mesh, 2)
        # batch 4: pod x data
        s4 = sharding.batch_spec(mesh, 4)
        print("s2", s2, "s4", s4)
        assert s4[0] == ("pod", "data")
        print("OK")
        """
    )
