"""Constraint-group driver: bucketing rules, grouped<->per-leaf parity for
every registered method (mixed tall/wide/stacked/complex leaves), the
ragged padded-megagroup schedule, the one-program-per-group compile
guarantee, grouped telemetry, and the batch-axis sharding hint."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, stiefel
from repro.core.api import (
    METHODS,
    ConstraintSet,
    GroupedDistances,
    OrthoState,
    leaf_distances,
    max_distance,
    orthogonal,
    plan_groups,
)

KEY = jax.random.PRNGKey(0)


def _mixed_tree():
    """Wide, tall, stacked and complex leaves: three f32 leaves share the
    (6, 16) manifold orientation (one of them stored tall, one stacked), a
    second f32 shape, and a complex leaf — 3 groups under "auto"."""
    return {
        "wide": stiefel.random_stiefel(KEY, (6, 16)),
        "tall": jnp.swapaxes(
            stiefel.random_stiefel(jax.random.PRNGKey(1), (6, 16)), -1, -2
        ),
        "stacked": stiefel.random_stiefel(jax.random.PRNGKey(2), (3, 6, 16)),
        "other": stiefel.random_stiefel(jax.random.PRNGKey(3), (4, 12)),
        "cplx": stiefel.random_stiefel(
            jax.random.PRNGKey(4), (6, 12), jnp.complex64
        ),
    }


def _grads_like(tree, seed=9):
    def g(x):
        r = jax.random.normal(jax.random.PRNGKey(seed), x.shape)
        if jnp.issubdtype(x.dtype, jnp.complexfloating):
            r = r + 1j * jax.random.normal(jax.random.PRNGKey(seed + 1), x.shape)
        return 0.1 * r.astype(x.dtype)

    return jax.tree.map(g, tree)


VARIANTS = {
    "pogo": {},
    "pogo_root": {"find_root": True},
    "landing": {},
    "landing_unsafe": {"safe_step": False},
    "landing_pc": {},
    "rgd_qr": {"retraction": "qr"},
    "rgd_polar": {"retraction": "polar"},
    "rgd_cayley": {"retraction": "cayley"},
    "rgd_ns": {"retraction": "newton_schulz"},
    "slpg": {},
    "rsdm": {"submanifold_dim": 4},
}


def _method_of(variant: str) -> str:
    return variant.split("_")[0] if variant.split("_")[0] in METHODS else variant


# ------------------------------------------------------------------ bucketing


def test_plan_buckets_by_manifold_shape_and_dtype():
    tree = _mixed_tree()
    leaves, treedef = jax.tree.flatten(tree)
    plan = plan_groups(leaves, treedef, "auto")
    keys = [(g.p, g.n, str(g.dtype)) for g in plan.groups]
    assert len(plan.groups) == 3
    assert (6, 12, "complex64") in keys
    assert (4, 12, "float32") in keys
    assert (6, 16, "float32") in keys
    big = plan.groups[keys.index((6, 16, "float32"))]
    # wide + tall + 3-stack share one group; tall member enters transposed
    assert big.batch == 5
    assert sorted(m.count for m in big.members) == [1, 1, 3]
    assert any(m.transpose for m in big.members)
    # key_base is assigned in flat-leaf order across ALL groups
    assert plan.n_matrices == 7
    assert plan.n_leaves == 5


def test_plan_per_leaf_is_one_group_per_leaf():
    tree = _mixed_tree()
    leaves, treedef = jax.tree.flatten(tree)
    plan = plan_groups(leaves, treedef, "per_leaf")
    assert len(plan.groups) == len(leaves)
    assert all(len(g.members) == 1 for g in plan.groups)


def test_plan_rejects_vectors_and_bad_grouping():
    with pytest.raises(ValueError, match="matrices"):
        plan_groups([jnp.ones((4,))], jax.tree.flatten([jnp.ones((4,))])[1], "auto")
    with pytest.raises(ValueError, match="grouping"):
        orthogonal("pogo", learning_rate=0.1, grouping="bogus")


def test_plan_is_static_and_hashable():
    tree = _mixed_tree()
    leaves, treedef = jax.tree.flatten(tree)
    a = plan_groups(leaves, treedef, "auto")
    b = plan_groups(leaves, treedef, "auto")
    assert a == b and hash(a) == hash(b)
    # static pytree node: zero leaves, rides inside jitted state for free
    assert jax.tree.leaves(a) == []


# -------------------------------------------------------------------- parity


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_grouped_matches_per_leaf(variant):
    """Acceptance: grouping="auto" reproduces grouping="per_leaf" updates
    and last_distance telemetry for every method, on a tree mixing wide,
    tall, stacked and complex leaves."""
    tree = _mixed_tree()
    grads = _grads_like(tree)
    outs = {}
    for grouping in ("auto", "per_leaf"):
        opt = orthogonal(
            _method_of(variant),
            learning_rate=0.1,
            grouping=grouping,
            **VARIANTS[variant],
        )
        state = opt.init(tree)
        u, state = opt.update(grads, state, tree)
        outs[grouping] = (u, state)
    u_a, s_a = outs["auto"]
    u_p, s_p = outs["per_leaf"]
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6, rtol=1e-5
        ),
        u_a,
        u_p,
    )
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6
        ),
        leaf_distances(s_a),
        leaf_distances(s_p),
    )
    np.testing.assert_allclose(
        float(max_distance(s_a)), float(max_distance(s_p)), atol=5e-6
    )


def test_grouped_matches_per_leaf_multi_step_with_base():
    """State threading (count, base momentum, rng) is grouping-agnostic."""
    from repro import optim

    tree = _mixed_tree()
    trajs = {}
    for grouping in ("auto", "per_leaf"):
        opt = orthogonal(
            "pogo",
            learning_rate=0.1,
            grouping=grouping,
            base_optimizer=optim.chain(optim.trace(0.9)),
        )
        params = tree
        state = opt.init(params)
        for i in range(4):
            grads = _grads_like(params, seed=20 + i)
            u, state = opt.update(grads, state, params)
            params = jax.tree.map(jnp.add, params, u)
        trajs[grouping] = params
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-5
        ),
        trajs["auto"],
        trajs["per_leaf"],
    )


# ---------------------------------------------------------- ragged megagroups


def _het_tree():
    """Heterogeneous shapes that the padded scheduler merges: four f32
    buckets (one stored tall) plus a complex leaf that must stay alone."""
    return {
        "a": stiefel.random_stiefel(KEY, (3, 8, 128)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(1), (2, 4, 96)),
        "tall": jnp.swapaxes(
            stiefel.random_stiefel(jax.random.PRNGKey(2), (6, 64)), -1, -2
        ),
        "d": stiefel.random_stiefel(jax.random.PRNGKey(3), (8, 120)),
        "cplx": stiefel.random_stiefel(
            jax.random.PRNGKey(4), (6, 48), jnp.complex64
        ),
    }


def test_padded_plan_merges_buckets_and_records_true_shapes():
    tree = _het_tree()
    leaves, treedef = jax.tree.flatten(tree)
    auto = plan_groups(leaves, treedef, "auto")
    padded = plan_groups(leaves, treedef, "padded")
    assert len(auto.groups) == 5
    # four real buckets merge into one (8, 128) megagroup; complex stays
    assert len(padded.groups) == 2
    mega = next(g for g in padded.groups if g.ragged)
    assert (mega.p, mega.n) == (8, 128) and mega.batch == 7
    # valid segments cover the batch in member order with true shapes
    assert sum(c for c, _, _ in mega.valid) == mega.batch
    assert set(mega.valid) >= {(3, 8, 128), (2, 4, 96), (1, 6, 64)}
    pv, nv = mega.valid_shape_arrays()
    assert pv.shape == (7,) and nv.shape == (7,)
    assert int(pv.max()) == 8 and int(nv.max()) == 128
    # members carry their true shape; matrix count is conserved
    for m in mega.members:
        assert m.shape_in(mega)[0] <= mega.p
    assert padded.n_matrices == auto.n_matrices == 8


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_padded_matches_per_leaf(variant):
    """ISSUE-5 acceptance: grouping="padded" reproduces per_leaf updates
    and telemetry per matrix for EVERY method on heterogeneous shapes
    (non-ragged-ready methods degrade to exact auto buckets)."""
    tree = _het_tree()
    grads = _grads_like(tree)
    outs = {}
    for grouping in ("padded", "per_leaf"):
        opt = orthogonal(
            _method_of(variant), learning_rate=0.1, grouping=grouping,
            **VARIANTS[variant],
        )
        state = opt.init(tree)
        u, state = opt.update(grads, state, tree)
        outs[grouping] = (u, state)
    u_a, s_a = outs["padded"]
    u_p, s_p = outs["per_leaf"]
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6, rtol=1e-5
        ),
        u_a, u_p,
    )
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6
        ),
        leaf_distances(s_a), leaf_distances(s_p),
    )


def test_padded_ragged_telemetry_masks_padding():
    """A padded megagroup's (B,) distances equal each member's TRUE-shape
    feasibility — padded rows/cols must contribute exactly zero."""
    tree = {k: v for k, v in _het_tree().items() if k != "cplx"}
    grads = _grads_like(tree)
    opt = orthogonal("pogo", learning_rate=0.1, grouping="padded")
    _, state = opt.update(grads, opt.init(tree), tree)
    ld = state.last_distance
    assert any(g.ragged for g in ld.plan.groups)
    # every matrix landed ~on-manifold; an unmasked residual would report
    # sqrt(pad_rows) >= 1 for the smaller members
    assert float(max_distance(state)) < 1e-4


def test_padded_constraint_set_roundtrip_and_driver():
    """Padded stacks as resting storage: from_tree/to_tree round-trip
    (crop the padding), the driver consumes the set through its own plan
    (stacked_plan preserves raggedness), and methods without ragged
    support refuse padded sets loudly."""
    tree = {k: v for k, v in _het_tree().items() if k != "cplx"}
    cs = ConstraintSet.from_tree(tree, grouping="padded")
    assert len(cs.stacks) == 1 and cs.stacks[0].shape == (7, 8, 128)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        cs.to_tree(), tree,
    )
    sp = cs.stacked_plan()
    assert sp.groups[0].ragged and sp.groups[0].valid == cs.plan.groups[0].valid

    grads = _grads_like(tree)
    gs = ConstraintSet.from_tree(grads, grouping="padded")
    opt = orthogonal("pogo", learning_rate=0.1)
    u_cs, s_cs = opt.update(gs, opt.init(cs), cs)
    opt_ref = orthogonal("pogo", learning_rate=0.1, grouping="per_leaf")
    u_t, s_t = opt_ref.update(grads, opt_ref.init(tree), tree)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6, rtol=1e-5
        ),
        cs.apply(u_cs).to_tree(),
        jax.tree.map(jnp.add, tree, u_t),
    )
    np.testing.assert_allclose(
        float(max_distance(s_cs)), float(max_distance(s_t)), atol=5e-6
    )
    with pytest.raises(ValueError, match="ragged"):
        orthogonal("rsdm", learning_rate=0.1).init(cs)


def test_padded_compiles_fewer_group_programs(monkeypatch):
    """The dispatch-count win itself: heterogeneous shapes trace the stage
    functions once per MEGAgroup under "padded", once per exact bucket
    under "auto"."""
    calls = {"n": 0}
    orig = api.Pogo.direction

    def counting(self, x, g, ctx):
        calls["n"] += 1
        return orig(self, x, g, ctx)

    monkeypatch.setattr(api.Pogo, "direction", counting)
    tree = {k: v for k, v in _het_tree().items() if k != "cplx"}
    grads = _grads_like(tree)
    for grouping, expect in (("auto", 4), ("padded", 1)):
        opt = orthogonal("pogo", learning_rate=0.1, grouping=grouping)
        state = opt.init(tree)
        calls["n"] = 0
        jax.jit(opt.update)(grads, state, tree)
        assert calls["n"] == expect, (grouping, calls["n"])


# ------------------------------------------------------------ compile counts


def test_same_shape_leaves_compile_one_group_program(monkeypatch):
    """Regression: N same-shape leaves must trace the stage functions ONCE
    under "auto" (one batched program per group), N times under
    "per_leaf" — the whole point of the grouped driver."""
    calls = {"n": 0}
    orig = api.Pogo.direction

    def counting(self, x, g, ctx):
        calls["n"] += 1
        return orig(self, x, g, ctx)

    monkeypatch.setattr(api.Pogo, "direction", counting)
    tree = {
        "a": stiefel.random_stiefel(KEY, (8, 16)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(1), (8, 16)),
        "c": jnp.swapaxes(stiefel.random_stiefel(jax.random.PRNGKey(2), (8, 16)), -1, -2),
    }
    grads = _grads_like(tree)
    for grouping, expect in (("auto", 1), ("per_leaf", 3)):
        opt = orthogonal("pogo", learning_rate=0.1, grouping=grouping)
        state = opt.init(tree)
        calls["n"] = 0
        jax.jit(opt.update)(grads, state, tree)
        assert calls["n"] == expect, (grouping, calls["n"])


# ----------------------------------------------------------------- telemetry


def test_grouped_distances_layout_and_views():
    tree = _mixed_tree()
    grads = _grads_like(tree)
    opt = orthogonal("pogo", learning_rate=0.1)
    state = opt.init(tree)
    u, state = opt.update(grads, state, tree)
    ld = state.last_distance
    assert isinstance(ld, GroupedDistances)
    assert len(ld.per_group) == len(ld.plan.groups)
    for g, arr in zip(ld.plan.groups, ld.per_group):
        assert arr.shape == (g.batch,) and arr.dtype == jnp.float32
    # leaf view has the param structure; global max agrees with the arrays
    view = leaf_distances(state)
    assert jax.tree.structure(view) == jax.tree.structure(tree)
    want = max(float(jnp.max(a)) for a in ld.per_group)
    np.testing.assert_allclose(float(max_distance(state)), want, rtol=1e-6)
    assert want < 1e-4  # pogo lands ~on-manifold in one step


def test_legacy_leafwise_state_no_longer_readable():
    """The PR-2 leaf-wise deprecation shim is gone (its one-release window
    passed): in-memory legacy states raise a pointed TypeError from both
    telemetry views. On-disk pre-group checkpoints keep restoring through
    checkpoint.restore (covered in tests/test_checkpoint.py)."""
    legacy = OrthoState(
        count=jnp.zeros([], jnp.int32),
        base_state=(),
        rng=jax.random.PRNGKey(0),
        last_distance={"a": jnp.asarray(0.25, jnp.float32),
                       "b": jnp.asarray(0.5, jnp.float32)},
        extras=(),
    )
    with pytest.raises(TypeError, match="GroupedDistances"):
        max_distance(legacy)
    with pytest.raises(TypeError, match="checkpoint.restore"):
        leaf_distances(legacy)


# ----------------------------------------------------------------------- rng


def test_rsdm_grouped_keys_are_per_matrix_and_grouping_invariant():
    """Stacked (B, 2) key fan-out: each matrix draws its own submanifold,
    identically under either grouping (keys indexed in flat-leaf order)."""
    tree = {
        "a": stiefel.random_stiefel(KEY, (6, 16)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(1), (2, 6, 16)),
    }
    grads = _grads_like(tree)
    us = {}
    for grouping in ("auto", "per_leaf"):
        opt = orthogonal(
            "rsdm", learning_rate=0.3, submanifold_dim=4, seed=7,
            grouping=grouping,
        )
        u, _ = opt.update(grads, opt.init(tree), tree)
        us[grouping] = u
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6
        ),
        us["auto"],
        us["per_leaf"],
    )
    # distinct matrices saw distinct keys: the two stacked updates differ
    u_b = np.asarray(us["auto"]["b"])
    assert not np.allclose(u_b[0], u_b[1])


def test_random_stiefel_stacked_matches_per_key_samples():
    keys = jax.random.split(KEY, 6).reshape(2, 3, 2)
    u = stiefel.random_stiefel_stacked(keys, (2, 3, 4, 8))
    assert u.shape == (2, 3, 4, 8)
    direct = stiefel.random_stiefel(keys[1, 2], (4, 8))
    np.testing.assert_allclose(np.asarray(u[1, 2]), np.asarray(direct), atol=1e-6)
    with pytest.raises(ValueError, match="batch dims"):
        stiefel.random_stiefel_stacked(keys, (3, 2, 4, 8))


# ------------------------------------------------------------- ConstraintSet


def test_constraint_set_roundtrip_and_update():
    """Stacked storage: from_tree/to_tree round-trips exactly (tall leaves
    included), is a pytree, and feeds the driver with zero repacking —
    producing the same trajectory as the leaf tree."""
    tree = _mixed_tree()
    cs = ConstraintSet.from_tree(tree)
    assert cs.plan.n_matrices == 7
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        cs.to_tree(),
        tree,
    )
    # pytree: stacked leaves flatten out, the plan is aux data
    assert len(jax.tree.leaves(cs)) == len(cs.stacks)

    grads = _grads_like(tree)
    gs = ConstraintSet.from_tree(grads)
    opt = orthogonal("pogo", learning_rate=0.1)
    u_cs, s_cs = opt.update(gs, opt.init(cs), cs)
    assert isinstance(u_cs, ConstraintSet)
    u_tree, s_tree = opt.update(grads, opt.init(tree), tree)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6, rtol=1e-5
        ),
        cs.apply(u_cs).to_tree(),
        jax.tree.map(jnp.add, tree, u_tree),
    )
    np.testing.assert_allclose(
        float(max_distance(s_cs)), float(max_distance(s_tree)), atol=5e-6
    )


def test_constraint_set_apply_rejects_foreign_plan():
    a = ConstraintSet.from_tree({"x": stiefel.random_stiefel(KEY, (4, 8))})
    b = ConstraintSet.from_tree({"x": stiefel.random_stiefel(KEY, (4, 12))})
    with pytest.raises(ValueError, match="plans differ"):
        a.apply(b)


# ------------------------------------------------------------------ sharding


@dataclasses.dataclass
class _StubMesh:
    shape: dict


def test_group_batch_spec_and_opt_state_specs():
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding

    mesh = _StubMesh(shape={"data": 2, "model": 2})
    assert sharding.group_batch_spec(mesh, 4) == P("data")
    assert sharding.group_batch_spec(mesh, 3) == P(None)

    tree = {
        "a": stiefel.random_stiefel(KEY, (8, 16)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(1), (3, 8, 16)),
    }
    opt = orthogonal("pogo", learning_rate=0.1)
    state = opt.init(tree)
    specs = sharding.opt_state_specs(state, tree, mesh)
    ld = specs.last_distance
    assert isinstance(ld, GroupedDistances)
    # one group of B=4: its (B,) distance array shards over the data axis
    assert ld.per_group == (P("data"),)


def test_group_sharding_hint_exposed():
    leaves, treedef = jax.tree.flatten([stiefel.random_stiefel(KEY, (2, 4, 8))])
    plan = plan_groups(leaves, treedef, "auto")
    assert plan.groups[0].sharding_hint() == ("batch", 2)
