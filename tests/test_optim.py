"""Optimizer substrate: transforms, schedules, partition, linearity (Def. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import stiefel


def _quadratic():
    target = jnp.arange(12.0).reshape(3, 4) / 10

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return loss, {"w": jnp.zeros((3, 4))}


@pytest.mark.parametrize(
    "opt",
    [
        optim.sgd(0.1),
        optim.sgd(0.1, momentum=0.9),
        optim.adam(0.05),
        optim.adamw(0.05, weight_decay=0.0),
        optim.vadam(0.05),
        optim.adafactor(0.05),
        optim.muon(0.05),
    ],
    ids=["sgd", "momentum", "adam", "adamw", "vadam", "adafactor", "muon"],
)
def test_optimizers_descend_quadratic(opt):
    loss, params = _quadratic()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_vadam_is_linear_def_1():
    """Def. 1: VAdam output is (scalar) * momentum(grad) — scaling the
    gradient stream scales the output, elementwise direction unchanged."""
    g = jax.random.normal(jax.random.PRNGKey(0), (6, 8))
    outs = {}
    for scale in (1.0, 7.0):
        opt = optim.chain(optim.scale_by_vadam())
        state = opt.init(g)
        out, state = opt.update(scale * g, state, g)
        outs[scale] = np.asarray(out)
    # direction identical (linear up to scalar), magnitudes normalized
    cos = np.sum(outs[1.0] * outs[7.0]) / (
        np.linalg.norm(outs[1.0]) * np.linalg.norm(outs[7.0])
    )
    assert cos > 0.9999


def test_adam_is_not_linear():
    """Adam's elementwise normalization breaks Def. 1 (paper Sec. 3.1)."""
    g1 = jnp.asarray([[1.0, 0.01]])
    opt = optim.chain(optim.scale_by_adam())
    state = opt.init(g1)
    out, _ = opt.update(g1, state, g1)
    out = np.asarray(out)[0]
    # elementwise normalization squashes the magnitude ratio toward 1
    assert abs(out[0] / out[1]) < 100 * 0.5


def test_vadam_equivariance_relative_gradient():
    """Eq. 8: Skew(X^H BO(G)) prop BO'(Skew(X^H G)) for linear BO without
    momentum state mixing — tested for the pure-scaling case."""
    key = jax.random.PRNGKey(1)
    x = stiefel.random_stiefel(key, (4, 10))
    g = jax.random.normal(jax.random.PRNGKey(2), (4, 10))
    opt = optim.chain(optim.scale_by_vadam(b1=0.0))  # no momentum: pure scale
    state = opt.init(g)
    bo_g, _ = opt.update(g, state, g)
    lhs = stiefel.relative_gradient(x, bo_g)
    rhs = stiefel.relative_gradient(x, g)
    # proportional: lhs = c * rhs
    c = float(jnp.vdot(rhs, lhs) / jnp.vdot(rhs, rhs))
    np.testing.assert_allclose(np.asarray(lhs), c * np.asarray(rhs), atol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    opt = optim.clip_by_global_norm(1.0)
    out, _ = opt.update(g, opt.init(g), g)
    assert float(optim.global_norm(out)) <= 1.0 + 1e-5


def test_clip_per_matrix_bounds_xi():
    g = jax.random.normal(jax.random.PRNGKey(3), (5, 6, 8)) * 100
    opt = optim.clip_per_matrix(1.0)
    out, _ = opt.update(g, opt.init(g), g)
    norms = jnp.sqrt(jnp.sum(out**2, axis=(-2, -1)))
    assert float(jnp.max(norms)) <= 1.0 + 1e-4


def test_schedules():
    s = optim.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) < 0.01
    lin = optim.linear(0.0, 1.0, 10)
    assert abs(float(lin(jnp.asarray(5))) - 0.5) < 1e-6


def test_partition_routes_by_label():
    params = {"ortho": jnp.ones((2, 4)), "dense": jnp.ones((3,))}
    labels = {"ortho": "orthogonal", "dense": "default"}
    opt = optim.partition(
        {
            "orthogonal": optim.sgd(1.0),
            "default": optim.sgd(0.0),  # frozen
        },
        labels,
    )
    g = jax.tree.map(jnp.ones_like, params)
    state = opt.init(params)
    upd, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd["ortho"]), -1.0)
    np.testing.assert_allclose(np.asarray(upd["dense"]), 0.0)


def test_partition_label_structure_mismatch_raises():
    params = {"a": jnp.ones(2)}
    with pytest.raises(ValueError):
        optim.partition({"default": optim.sgd(1.0)}, {"b": "default", "c": "default"}).init(params)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128))}
    opt = optim.chain(optim.scale_by_adafactor())
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state < 64 * 128 / 8  # O(n+m), not O(nm)
