"""Optional-hypothesis shim: property tests skip on bare environments.

Usage (at the top of a test module)::

    from _hypothesis_compat import given, settings, st

When ``hypothesis`` is installed these are the real thing. When it is not,
``@given(...)`` replaces the test with a skip (via
``pytest.importorskip("hypothesis")``) while every deterministic test in
the module keeps running — an unconditional top-level import would fail
the whole module at collection time instead.
"""

from __future__ import annotations


try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # bare environment: skip property tests only
    HAVE_HYPOTHESIS = False

    def settings(*args, **kwargs):
        def deco(f):
            return f

        return deco

    def given(*args, **kwargs):
        def deco(f):
            # No functools.wraps: the wrapper must present a ZERO-arg
            # signature, else pytest treats the strategy parameters as
            # fixtures and errors at setup.
            def wrapper():
                import pytest

                pytest.importorskip("hypothesis")

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco

    class _Strategies:
        """Placeholder strategies: module-level ``st.integers(...)`` etc.
        must evaluate during collection; the values are never used because
        ``given`` skips the test body."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            return strategy

    st = _Strategies()
