"""Serving subsystem: paged KV cache, continuous batching, folding.

The load-bearing guarantee is token identity: a burst of requests served
concurrently through the paged engine must produce EXACTLY the tokens the
sequential one-request-at-a-time dense-cache oracle produces — any
cross-request cache leakage, masking slip, or paging bug breaks greedy
argmax somewhere in a 32-request burst. Parity runs in float32 so the
comparison is bit-meaningful.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ortho
from repro.models import transformer as tfm
from repro.models.transformer import CacheLeafLayout
from repro.serve import (
    AdmissionError,
    BlockAllocator,
    BlockTables,
    FoldFeasibilityError,
    RejectReason,
    Request,
    RequestState,
    ServeEngine,
    blocks_needed,
    extract_constraint_set,
    fold_constraint_set,
    generate_reference,
    reset_slot,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm_f32():
    """fp32 smoke model: greedy argmax comparisons are bit-meaningful."""
    cfg = dataclasses.replace(
        get_config("smollm-360m", smoke=True), compute_dtype="float32"
    )
    params = tfm.init_params(KEY, cfg)
    return params, cfg


def _prompt(rng, lo=3, hi=10):
    return rng.integers(0, 100, size=(int(rng.integers(lo, hi + 1)),)).astype(
        np.int32
    )


# --------------------------------------------------------------- kv_cache


class TestBlockAllocator:
    def test_block_zero_reserved(self):
        a = BlockAllocator(8)
        got = a.alloc(7)
        assert got is not None and 0 not in got and len(set(got)) == 7
        assert a.alloc(1) is None  # pool of 8 has 7 usable blocks

    def test_alloc_is_all_or_nothing(self):
        a = BlockAllocator(6)  # 5 usable
        first = a.alloc(3)
        assert first is not None
        assert a.alloc(3) is None
        assert a.n_free == 2  # failed alloc took nothing
        assert a.alloc(2) is not None
        assert a.n_free == 0

    def test_free_returns_blocks(self):
        a = BlockAllocator(6)
        blocks = a.alloc(4)
        a.free(blocks)
        assert a.n_free == 5 and a.n_used == 0

    def test_double_free_raises(self):
        a = BlockAllocator(6)
        blocks = a.alloc(2)
        a.free(blocks)
        with pytest.raises(ValueError):
            a.free(blocks)

    def test_foreign_free_raises(self):
        a = BlockAllocator(6)
        with pytest.raises(ValueError):
            a.free([3])


class TestBlockTables:
    def test_assign_release_roundtrip(self):
        t = BlockTables(2, 4)
        t.assign(0, [5, 7, 2])
        assert t.owned(0) == [5, 7, 2]
        assert list(t.array[0]) == [5, 7, 2, 0]  # zero-padded row
        assert list(t.array[1]) == [0, 0, 0, 0]
        assert t.release(0) == [5, 7, 2]
        assert list(t.array[0]) == [0, 0, 0, 0]

    def test_double_assign_raises(self):
        t = BlockTables(2, 4)
        t.assign(0, [1])
        with pytest.raises(ValueError):
            t.assign(0, [2])


def test_blocks_needed_ceil():
    assert blocks_needed(1, 4) == 1
    assert blocks_needed(4, 4) == 1
    assert blocks_needed(5, 4) == 2
    assert blocks_needed(16, 4) == 4


def test_reset_slot_is_layout_driven_not_dtype_heuristic():
    """Regression: the retired reset heuristic skipped int32 leaves and
    leaves with shape[0] == 1; layout metadata must reset ANY dtype that
    has a slot axis and leave pool leaves alone."""
    caches = {
        "state_f": jnp.ones((4, 3), jnp.float32),
        "state_i32": jnp.ones((4, 3), jnp.int32),   # heuristic missed this
        "state_ax1": jnp.ones((2, 4, 3), jnp.float32),
        "pool": jnp.ones((8, 2), jnp.float32),      # shared: never reset
    }
    layouts = {
        "state_f": CacheLeafLayout("state", 0),
        "state_i32": CacheLeafLayout("state", 0),
        "state_ax1": CacheLeafLayout("state", 1),
        "pool": CacheLeafLayout("pool", None),
    }
    out = reset_slot(caches, layouts, 1)
    for name in ("state_f", "state_i32"):
        arr = np.asarray(out[name])
        assert arr[1].sum() == 0, f"{name} slot row not reset"
        assert arr[0].sum() == 3 and arr[2:].sum() == 6, f"{name} bled"
    arr = np.asarray(out["state_ax1"])
    assert arr[:, 1].sum() == 0 and arr[:, 0].sum() == 6
    assert np.asarray(out["pool"]).sum() == 16


# -------------------------------------------------------------- admission


class TestAdmission:
    def _engine(self, smollm_f32, **kw):
        params, cfg = smollm_f32
        kw.setdefault("n_slots", 2)
        kw.setdefault("n_blocks", 9)
        kw.setdefault("block_size", 4)
        return ServeEngine(params, cfg, **kw)

    def test_empty_prompt_rejected(self, smollm_f32):
        eng = self._engine(smollm_f32)
        with pytest.raises(AdmissionError) as e:
            eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))
        assert e.value.reason is RejectReason.EMPTY_PROMPT

    def test_too_long_rejected(self, smollm_f32):
        eng = self._engine(smollm_f32)  # 8 usable blocks * 4 = 32 positions
        prompt = np.zeros((40,), np.int32)
        rej = eng.try_submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        assert rej.reason is RejectReason.TOO_LONG
        assert rej.retry_after_ticks is None  # permanent for this shape

    def test_too_long_boundary_exact_capacity(self, smollm_f32):
        """Boundary pin: prompt+max_new == usable capacity is admissible;
        one more position (== n_blocks * block_size, counting the reserved
        null block) is TOO_LONG."""
        eng = self._engine(smollm_f32)  # n_blocks=9, block_size=4
        cap = (9 - 1) * 4  # usable positions (block 0 reserved)
        ok = Request(uid=0, prompt=np.zeros((cap - 4,), np.int32),
                     max_new_tokens=4)
        assert eng.try_submit(ok) is None
        over = Request(uid=1, prompt=np.zeros((9 * 4 - 4,), np.int32),
                       max_new_tokens=4)
        rej = eng.try_submit(over)
        assert rej is not None and rej.reason is RejectReason.TOO_LONG

    def test_zero_max_new_tokens_rejected(self, smollm_f32):
        """Pinned: max_new_tokens < 1 is a typed rejection, not silent
        one-token generation (the pre-robustness engine emitted 1 token)."""
        eng = self._engine(smollm_f32)
        rej = eng.try_submit(
            Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=0)
        )
        assert rej is not None and rej.reason is RejectReason.ZERO_NEW_TOKENS
        with pytest.raises(AdmissionError) as e:
            eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=-1))
        assert e.value.reason is RejectReason.ZERO_NEW_TOKENS

    def test_queue_full_rejected_and_counted(self, smollm_f32):
        eng = self._engine(smollm_f32, max_queue=1)
        rng = np.random.default_rng(0)
        eng.submit(Request(uid=0, prompt=_prompt(rng)))
        rej = eng.try_submit(Request(uid=1, prompt=_prompt(rng)))
        assert rej.reason is RejectReason.QUEUE_FULL
        assert rej.retry_after_ticks >= 1  # backpressure hint always set
        assert eng.stats["rejected"] == {"queue_full": 1}

    def test_queue_full_then_drain_admits_resubmit(self, smollm_f32):
        """A full queue that drains between submits must accept the retry
        within the hinted tick budget."""
        eng = self._engine(smollm_f32, max_queue=2, n_blocks=17)
        rng = np.random.default_rng(7)
        for uid in range(2):
            eng.submit(Request(uid=uid, prompt=_prompt(rng, 3, 4),
                               max_new_tokens=2))
        eng.step()  # both into slots, freeing the queue
        for uid in range(2, 4):  # refill the queue to capacity
            eng.submit(Request(uid=uid, prompt=_prompt(rng, 3, 4),
                               max_new_tokens=2))
        late = Request(uid=99, prompt=_prompt(rng, 3, 4), max_new_tokens=2)
        rej = eng.try_submit(late)
        assert rej is not None and rej.reason is RejectReason.QUEUE_FULL
        assert rej.retry_after_ticks >= 1
        # drive the engine the hinted number of ticks and retry until the
        # queue drains; the engine must accept before it goes idle
        for _ in range(200):
            for _ in range(rej.retry_after_ticks):
                eng.step()
            rej = eng.try_submit(late)
            if rej is None:
                break
            assert rej.reason is RejectReason.QUEUE_FULL
        assert rej is None, "queue never drained enough to admit the retry"
        eng.run()
        assert late.out_tokens == generate_reference(
            *smollm_f32, late.prompt, late.max_new_tokens
        )

    def test_fifo_head_of_line_blocks(self, smollm_f32):
        """A big head request waiting for blocks must not be overtaken by
        a small later one, even when the small one would fit now."""
        eng = self._engine(smollm_f32, n_slots=2, n_blocks=7, block_size=2)
        rng = np.random.default_rng(1)
        # A: 4 blocks of the 6 usable, decoding for a while;
        # B: needs 4 (must wait for A); C: tiny, would fit right now
        a = Request(uid=0, prompt=_prompt(rng, 2, 2), max_new_tokens=6)
        b = Request(uid=1, prompt=_prompt(rng, 4, 4), max_new_tokens=4)
        c = Request(uid=2, prompt=_prompt(rng, 1, 1), max_new_tokens=1)
        for r in (a, b, c):
            eng.submit(r)
        eng.step()
        admitted = {r.uid for r in eng.slot_req if r is not None}
        assert 0 in admitted and 2 not in admitted  # C queued behind B
        eng.run()
        assert b.t_admit <= c.t_admit
        assert len(eng.finished) == 3

    def test_admission_order_matches_submission(self, smollm_f32):
        eng = self._engine(smollm_f32, n_slots=2, n_blocks=17)
        rng = np.random.default_rng(2)
        reqs = [
            Request(uid=i, prompt=_prompt(rng), max_new_tokens=int(rng.integers(1, 6)))
            for i in range(10)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run()
        admits = [r.t_admit for r in reqs]
        assert admits == sorted(admits)


# ----------------------------------------------------- engine under load


def test_slot_reuse_and_block_accounting(smollm_f32):
    params, cfg = smollm_f32
    eng = ServeEngine(params, cfg, n_slots=2, n_blocks=17, block_size=4)
    rng = np.random.default_rng(3)
    for uid in range(7):  # > n_slots: slots must be recycled
        eng.submit(Request(uid=uid, prompt=_prompt(rng), max_new_tokens=3))
    finished = eng.run()
    assert len(finished) == 7
    per_slot = eng.stats["admissions_per_slot"]
    assert sum(per_slot) == 7 and max(per_slot) > 1
    # every block returned to the pool, every table row cleared
    assert eng.allocator.n_used == 0
    assert eng.allocator.n_free == 16
    assert np.all(eng.tables.array == 0)


def test_prefill_does_not_touch_neighbor_blocks(smollm_f32):
    """Direct leakage probe: chunk-prefilling one slot must leave every
    pool block owned by another slot byte-identical (the retired per-slot
    prefill pushed pad tokens through ALL slots' caches)."""
    params, cfg = smollm_f32
    eng = ServeEngine(params, cfg, n_slots=2, n_blocks=33, block_size=4,
                      prefill_chunk=4)
    rng = np.random.default_rng(4)
    eng.submit(Request(uid=0, prompt=_prompt(rng, 8, 8), max_new_tokens=8))
    while eng.slot_state[0] != "decode":
        eng.step()
    victim_blocks = np.asarray(eng.tables.owned(0))

    def pool_leaves(caches):
        return [
            leaf for leaf, lay in zip(jax.tree.leaves(caches),
                                      jax.tree.leaves(eng.layouts))
            if lay.role == "pool"
        ]

    before = [np.asarray(leaf[..., victim_blocks, :, :, :].copy())
              if leaf.ndim > 4 else np.asarray(leaf[victim_blocks].copy())
              for leaf in pool_leaves(eng.caches)]
    # admit + chunk-prefill a second request while slot 0 sits in decode
    eng.submit(Request(uid=1, prompt=_prompt(rng, 9, 9), max_new_tokens=2))
    eng._admit()
    assert eng.slot_state[1] == "prefill"
    eng._prefill_tick()
    after = [np.asarray(leaf[..., victim_blocks, :, :, :])
             if leaf.ndim > 4 else np.asarray(leaf[victim_blocks])
             for leaf in pool_leaves(eng.caches)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


def test_burst_32_requests_token_identical_to_sequential_reference(smollm_f32):
    """Acceptance: a 32-request burst through the paged continuous-batching
    engine reproduces the sequential one-request-at-a-time oracle exactly.
    Token identity across the whole burst is the zero-leakage assertion —
    any foreign KV read shifts some greedy argmax."""
    params, cfg = smollm_f32
    rng = np.random.default_rng(5)
    reqs = [
        Request(uid=i, prompt=_prompt(rng, 3, 12),
                max_new_tokens=int(rng.integers(2, 9)))
        for i in range(32)
    ]
    eng = ServeEngine(params, cfg, n_slots=4, n_blocks=65, block_size=4,
                      prefill_chunk=5)
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 32
    for r in reqs:
        ref = generate_reference(params, cfg, r.prompt, r.max_new_tokens)
        assert r.out_tokens == ref, (
            f"request {r.uid} diverged from the sequential reference"
        )
    # recovery-path telemetry must exist and stay silent on the happy path
    s = eng.stats
    assert s["finished"] == 32
    assert all(r.state is RequestState.FINISHED for r in reqs)
    for k in ("preemptions", "swapped_out", "swapped_in", "preempted",
              "expired", "cancelled", "failed", "watchdog_trips",
              "weight_drift_trips"):
        assert s[k] == 0, f"stats[{k!r}] nonzero on a no-fault burst"


def test_chunked_and_whole_prefill_are_equivalent(smollm_f32):
    params, cfg = smollm_f32
    prompt = np.arange(11, dtype=np.int32)
    outs = []
    for chunk in (3, 64):  # 3 forces 4 chunks incl. a ragged tail; 64 = whole
        eng = ServeEngine(params, cfg, n_slots=1, n_blocks=17, block_size=4,
                          prefill_chunk=chunk)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        outs.append(eng.run()[0].out_tokens)
    assert outs[0] == outs[1]
    assert outs[0] == generate_reference(params, cfg, prompt, 6)


def test_greedy_golden_is_stable(smollm_f32):
    """Literal pin: seed-0 params, fixed prompt. Catches silent numerics
    drift in the serving path that parity-vs-reference can't (both sides
    drifting together)."""
    params, cfg = smollm_f32
    eng = ServeEngine(params, cfg, n_slots=2, n_blocks=17, block_size=4)
    eng.submit(Request(uid=0, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=6))
    out = eng.run()[0].out_tokens
    assert out == GOLDEN_SMOLLM_SEED0


GOLDEN_SMOLLM_SEED0 = [354, 439, 297, 415, 415, 415]


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "falcon-mamba-7b"])
def test_recurrent_arch_burst_matches_reference(arch):
    """Hybrid/recurrent archs carry per-slot scan state through decode;
    masked rows must keep their state (not have it recomputed from pad
    tokens) while other slots prefill."""
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              compute_dtype="float32")
    params = tfm.init_params(KEY, cfg)
    rng = np.random.default_rng(6)
    reqs = [
        Request(uid=i, prompt=_prompt(rng, 3, 9), max_new_tokens=4)
        for i in range(3)
    ]
    eng = ServeEngine(params, cfg, n_slots=2, n_blocks=17, block_size=4,
                      prefill_chunk=4)
    for r in reqs:
        eng.submit(r)
    assert len(eng.run()) == 3
    for r in reqs:
        ref = generate_reference(params, cfg, r.prompt, r.max_new_tokens)
        assert r.out_tokens == ref


# -------------------------------------------------------------------- fold


class TestFold:
    def test_roundtrip_preserves_params(self, smollm_f32):
        params, cfg = smollm_f32
        params = ortho.project_init(params, cfg)
        cs = extract_constraint_set(params, cfg)
        res = fold_constraint_set(params, cfg, cs)
        assert res.n_leaves == len(ortho.extract_constrained(params, cfg))
        assert res.max_distance < 1e-3
        for a, b in zip(ortho.extract_constrained(params, cfg),
                        ortho.extract_constrained(res.params, cfg)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_infeasible_stack_raises(self, smollm_f32):
        params, cfg = smollm_f32
        params = ortho.project_init(params, cfg)
        leaves = ortho.extract_constrained(params, cfg)
        bad = ortho.merge_constrained(params, cfg,
                                      tuple(2.0 * leaf for leaf in leaves))
        cs = extract_constraint_set(bad, cfg)
        with pytest.raises(FoldFeasibilityError) as e:
            fold_constraint_set(params, cfg, cs)
        assert e.value.distance > e.value.atol
        assert e.value.path  # worst offender is named

    def test_no_constrained_families_raises(self, smollm_f32):
        params, cfg = smollm_f32
        cfg_none = dataclasses.replace(cfg, ortho_families=())
        with pytest.raises(ValueError):
            extract_constraint_set(params, cfg_none)

    def test_folded_params_serve(self, smollm_f32):
        """End-to-end handoff: fold -> engine -> matches the reference on
        the folded params."""
        params, cfg = smollm_f32
        params = ortho.project_init(params, cfg)
        cs = extract_constraint_set(params, cfg)
        folded = fold_constraint_set(params, cfg, cs).params
        prompt = np.arange(7, dtype=np.int32)
        eng = ServeEngine(folded, cfg, n_slots=2, n_blocks=17, block_size=4)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        out = eng.run()[0].out_tokens
        assert out == generate_reference(folded, cfg, prompt, 5)
