"""Serving engine: continuous batching, slot lifecycle, output sanity."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m", smoke=True)
    params = tfm.init_params(KEY, cfg)
    return ServeEngine(params, cfg, n_slots=2, cache_len=64)


def test_serves_more_requests_than_slots(engine):
    rng = np.random.default_rng(0)
    for uid in range(5):  # > n_slots
        prompt = rng.integers(0, 100, size=(6,)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=4))
    finished = engine.run()
    assert len(finished) == 5
    for r in finished:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < engine.cfg.padded_vocab for t in r.out_tokens)


def test_greedy_is_deterministic():
    cfg = get_config("smollm-360m", smoke=True)
    params = tfm.init_params(KEY, cfg)
    outs = []
    for _ in range(2):
        eng = ServeEngine(params, cfg, n_slots=1, cache_len=64)
        prompt = np.arange(5, dtype=np.int32)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
        finished = eng.run()
        outs.append(finished[0].out_tokens)
    assert outs[0] == outs[1]
