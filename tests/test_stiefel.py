"""Stiefel-manifold math: identities, projections, property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import stiefel

KEY = jax.random.PRNGKey(0)


def _rand(shape, key=KEY, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


@pytest.mark.parametrize("shape", [(8, 16), (3, 3), (5, 40), (2, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.complex64])
def test_random_stiefel_on_manifold(shape, dtype):
    x = stiefel.random_stiefel(KEY, shape, dtype)
    assert x.shape == shape
    d = stiefel.manifold_distance(x)
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=5e-5)


def test_skew_sym_decomposition():
    a = _rand((4, 7, 7))
    np.testing.assert_allclose(
        np.asarray(stiefel.skew(a) + stiefel.sym(a)), np.asarray(a),
        rtol=1e-6, atol=1e-6,
    )
    s = stiefel.skew(a)
    np.testing.assert_allclose(
        np.asarray(s), -np.asarray(jnp.swapaxes(s, -1, -2)), rtol=1e-6
    )


def test_riemannian_gradient_factored_form_matches_definition():
    """X Skew(X^H G) computed the O(p^2 n) way == the (n,n) definition."""
    x = stiefel.random_stiefel(KEY, (6, 24))
    g = _rand((6, 24), jax.random.PRNGKey(1))
    direct = x @ stiefel.relative_gradient(x, g)
    fact = stiefel.riemannian_gradient(x, g)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(fact), atol=1e-5)


def test_riemannian_gradient_is_tangent():
    x = stiefel.random_stiefel(KEY, (6, 24))
    g = _rand((6, 24), jax.random.PRNGKey(1))
    r = stiefel.riemannian_gradient(x, g)
    # tangency: R X^H + X R^H = 0
    t = r @ x.T + x @ r.T
    np.testing.assert_allclose(np.asarray(t), 0.0, atol=1e-5)


def test_grad_and_normal_orthogonal_on_manifold():
    """The paper's Fig. 2 geometry: <grad, normal> = 0 on the manifold."""
    x = stiefel.random_stiefel(KEY, (8, 20))
    g = _rand((8, 20), jax.random.PRNGKey(2))
    r = stiefel.riemannian_gradient(x, g)
    n = stiefel.penalty_grad(x)
    ip = float(jnp.sum(r * n))
    assert abs(ip) < 1e-4


@pytest.mark.parametrize("proj", [stiefel.project_qr, stiefel.project_polar,
                                  stiefel.project_newton_schulz])
def test_projections_land_on_manifold(proj):
    x = stiefel.random_stiefel(KEY, (8, 20)) + 0.05 * _rand((8, 20))
    y = proj(x)
    assert float(stiefel.manifold_distance(y)) < 1e-4


def test_polar_projection_is_closest():
    """Polar is the metric projection: no retraction lands closer."""
    x = stiefel.random_stiefel(KEY, (6, 12)) + 0.08 * _rand((6, 12))
    polar = stiefel.project_polar(x)
    qr = stiefel.project_qr(x)
    d_polar = float(jnp.linalg.norm(x - polar))
    d_qr = float(jnp.linalg.norm(x - qr))
    assert d_polar <= d_qr + 1e-6


def test_cayley_retraction_exact():
    x = stiefel.random_stiefel(KEY, (5, 9))
    omega = stiefel.skew(_rand((5, 5), jax.random.PRNGKey(3)))
    y = stiefel.retraction_cayley(x, 0.3 * omega)
    assert float(stiefel.manifold_distance(y)) < 1e-5


def test_tangent_projection_idempotent_and_tangent():
    x = stiefel.random_stiefel(KEY, (6, 14))
    v = _rand((6, 14), jax.random.PRNGKey(4))
    t = stiefel.tangent_project(x, v)
    # tangency
    c = t @ x.T + x @ t.T
    np.testing.assert_allclose(np.asarray(c), 0.0, atol=1e-5)
    # idempotency
    t2 = stiefel.tangent_project(x, t)
    np.testing.assert_allclose(np.asarray(t), np.asarray(t2), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(2, 8),
    extra=st.integers(0, 12),
    seed=st.integers(0, 2**30),
    eta=st.floats(0.01, 0.3),
)
def test_pogo_bound_prop_3_2(p, extra, seed, eta):
    """Prop 3.2: ||M M^T - I|| <= eta^2 ||S^2|| for X on the manifold."""
    n = p + extra
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = stiefel.random_stiefel(k1, (p, n), jnp.float64 if False else jnp.float32)
    g = jax.random.normal(k2, (p, n))
    s = stiefel.relative_gradient(x, g)
    m = x - eta * (x @ s)
    lhs = float(stiefel.manifold_distance(m))
    rhs = eta**2 * float(jnp.linalg.norm(s @ s))
    assert lhs <= rhs + 1e-3


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(2, 6),
    extra=st.integers(0, 8),
    seed=st.integers(0, 2**30),
)
def test_pogo_update_stays_on_manifold(p, extra, seed):
    """Thm 3.5 (one step): xi < 1 => POGO with lam=1/2 stays ~on manifold."""
    n = p + extra
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = stiefel.random_stiefel(k1, (p, n))
    g = jax.random.normal(k2, (p, n))
    g = g / jnp.maximum(jnp.linalg.norm(g), 1.0)  # ||G|| <= 1
    # xi = 0.1: Prop 3.3 bound gives dist <~ (3/4 + xi^2/4) * xi^4 ~ 8e-5
    y = stiefel.pogo_update(x, g, eta=0.1, lam=0.5)
    assert float(stiefel.manifold_distance(y)) < 1e-3
