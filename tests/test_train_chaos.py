"""Chaos-tested self-healing training (DESIGN.md §Training robustness).

The training fault hooks of :mod:`repro.faults` (nan_grad, drift_inject,
corrupt_checkpoint, delay_step) driven through ``train.loop.train``:
one-shot/replay semantics, divergence rollback with poison-batch skip,
checkpoint-corruption degradation under rollback, and the headline
acceptance run — a seeded 3-fault schedule that drains to completion
with every fault logged, replays bit-identically, and never lets the
feasibility residual exceed the watchdog's hard threshold for more than
one step.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.faults import TRAIN_FAULT_KINDS, FaultEvent, FaultPlan
from repro.models import ortho, transformer as tfm
from repro.train.loop import LoopConfig, train
from repro.train.train_step import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


def _setup(steps=16, watchdog=None, grouping="auto"):
    cfg = get_config("smollm-360m", smoke=True)
    params = ortho.project_init(tfm.init_params(KEY, cfg), cfg)
    tc = TrainConfig(
        warmup_steps=2, decay_steps=steps, learning_rate=1e-2,
        pogo_learning_rate=0.3, ortho_watchdog=watchdog,
        ortho_grouping=grouping,
    )
    step_fn, optimizer = make_train_step(cfg, tc)
    opt_state = optimizer.init(params)
    data = DataIterator(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1)
    )
    return cfg, jax.jit(step_fn), params, opt_state, data


def _ortho_drift(cfg):
    """drift_inject target that scales only the constrained leaves — the
    families the watchdog can repair exactly (polar-factor invariance)."""

    def apply(params, scale):
        labels = ortho.label_tree(params, cfg)
        return jax.tree.map(
            lambda x, l: x * (1.0 + scale) if l == "orthogonal" else x,
            params, labels,
        )

    return apply


# ------------------------------------------------------- train fault hooks


def test_random_train_plan_is_deterministic():
    a = FaultPlan.random(7, n_events=6, max_tick=20, kinds=TRAIN_FAULT_KINDS)
    b = FaultPlan.random(7, n_events=6, max_tick=20, kinds=TRAIN_FAULT_KINDS)
    assert a.events == b.events
    assert all(e.kind in TRAIN_FAULT_KINDS for e in a.events)


def test_nan_grad_is_one_shot():
    plan = FaultPlan((FaultEvent("nan_grad", tick=3),))
    assert not plan.nan_grad(2)
    assert plan.nan_grad(3)
    assert not plan.nan_grad(3)  # spent: a rollback replay never re-fires
    assert plan.fired == [(3, "nan_grad", None)]


def test_drift_scale_is_one_shot():
    plan = FaultPlan((FaultEvent("drift_inject", tick=2, scale=0.25),))
    assert plan.drift_scale(1) is None
    assert plan.drift_scale(2) == pytest.approx(0.25)
    assert plan.drift_scale(2) is None
    assert plan.fired == [(2, "drift_inject", 0.25)]


def test_step_delay_honors_duration():
    plan = FaultPlan((FaultEvent("delay_step", tick=1, duration=2, scale=0.01),))
    assert plan.step_delay(0) == 0.0
    assert plan.step_delay(1) == pytest.approx(0.01)
    assert plan.step_delay(2) == pytest.approx(0.01)  # not one-shot
    assert plan.step_delay(3) == 0.0


def test_corrupt_checkpoint_flips_committed_bytes(tmp_path):
    from repro.checkpoint import checkpoint as ckpt

    d = str(tmp_path)
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    path = ckpt.save(d, 5, tree)
    plan = FaultPlan((FaultEvent("corrupt_checkpoint", tick=3),))
    assert plan.corrupt_checkpoint(5, path)
    assert not plan.corrupt_checkpoint(5, path)  # one-shot
    # the crc layer detects the flip and restore_latest degrades past it
    with pytest.warns(RuntimeWarning, match="corrupt"):
        step, _ = ckpt.restore_latest(d, tree)
    assert step is None  # only checkpoint was corrupt — nothing older


# ------------------------------------------------------ divergence rollback


def test_rollback_recovers_from_nan(tmp_path):
    """A nan_grad fault poisons step 5; the loop rolls back to the last
    checkpoint, skips the poison batch, and drains to completion with
    finite loss."""
    steps = 10
    cfg, step_fn, params, opt_state, data = _setup(steps)
    plan = FaultPlan((FaultEvent("nan_grad", tick=5),))
    lc = LoopConfig(
        total_steps=steps, log_every=1, checkpoint_dir=str(tmp_path),
        save_every=4, rollback=True,
    )
    p, o, step, hist = train(
        step_fn, params, opt_state, data, lc, fault_plan=plan
    )
    assert step == steps
    assert [f[1] for f in plan.fired] == ["nan_grad"]
    final = hist[-1][1]
    assert np.isfinite(final["loss"])
    assert final["health_finite"] == 1.0
    # every post-rollback logged step is healthy
    assert all(h[1]["health_finite"] == 1.0 for h in hist)


def test_rollback_requires_checkpoint_dir():
    cfg, step_fn, params, opt_state, data = _setup(2)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        train(
            step_fn, params, opt_state, data,
            LoopConfig(total_steps=2, rollback=True),
        )


def test_rollback_budget_exhausts(tmp_path):
    """A step function that diverges every time exhausts max_rollbacks
    instead of looping forever."""
    cfg, step_fn, params, opt_state, data = _setup(4)

    def always_nan(p, o, b):
        p2, o2, m = step_fn(p, o, b)
        m = dict(m)
        m["loss"] = jnp.float32(np.nan)
        return p2, o2, m

    lc = LoopConfig(
        total_steps=4, checkpoint_dir=str(tmp_path), save_every=100,
        rollback=True, max_rollbacks=2,
    )
    with pytest.raises(RuntimeError, match="rollback budget"):
        train(always_nan, params, opt_state, data, lc)


def test_empty_plan_matches_no_plan(tmp_path):
    """A FaultPlan with no events must not perturb training at all — the
    hooks are host-side guards, nothing reaches the compiled step."""
    steps = 6
    cfg, step_fn, params, opt_state, data = _setup(steps)
    lc = LoopConfig(total_steps=steps, log_every=1)
    _, _, _, h_none = train(step_fn, params, opt_state, data, lc)

    cfg, step_fn2, params2, opt_state2, data2 = _setup(steps)
    _, _, _, h_empty = train(
        step_fn2, params2, opt_state2, data2, lc, fault_plan=FaultPlan(())
    )
    assert [h[1]["loss"] for h in h_none] == [h[1]["loss"] for h in h_empty]


# ----------------------------------------------------- the acceptance chaos


def _chaos_plan():
    """nan_grad, drift_inject, corrupt_checkpoint at 3 distinct steps."""
    return FaultPlan((
        FaultEvent("drift_inject", tick=4, scale=0.2),
        FaultEvent("corrupt_checkpoint", tick=6),
        FaultEvent("nan_grad", tick=9),
    ))


def _chaos_run(tmp_dir, steps=14):
    wd = core.WatchdogConfig()
    cfg, step_fn, params, opt_state, data = _setup(steps, watchdog=wd)
    plan = _chaos_plan()
    lc = LoopConfig(
        total_steps=steps, log_every=1, checkpoint_dir=tmp_dir,
        save_every=4, rollback=True,
    )
    p, o, step, hist = train(
        step_fn, params, opt_state, data, lc,
        fault_plan=plan, drift_apply=_ortho_drift(cfg),
    )
    return p, o, step, hist, plan, wd


def test_chaos_drains_and_replays_identically(tmp_path):
    """The headline gate: a 3-fault schedule (drift_inject at 4,
    corrupt_checkpoint at 6, nan_grad at 9) drains to completion, logs
    every fault, keeps the feasibility residual under the hard threshold
    at every recorded step (the in-step repair makes the drift invisible
    to the recorded post-step telemetry), lands within tolerance of the
    no-fault run, and replayed from scratch executes identically."""
    steps = 14
    p1, o1, s1, hist1, plan1, wd = _chaos_run(str(tmp_path / "a"), steps)
    assert s1 == steps
    fired_kinds = sorted(f[1] for f in plan1.fired)
    assert fired_kinds == ["corrupt_checkpoint", "drift_inject", "nan_grad"]

    # recorded (post-repair) residual never exceeds the hard threshold
    dists = [h[1]["ortho_distance"] for h in hist1]
    assert max(dists) < wd.hard, dists
    assert all(np.isfinite(h[1]["loss"]) for h in hist1)
    assert hist1[-1][1]["health_finite"] == 1.0

    # replay: same seeds, same schedule -> identical fault log (details
    # that embed the checkpoint dir are compared by basename) and
    # bit-identical final params
    p2, o2, s2, hist2, plan2, _ = _chaos_run(str(tmp_path / "b"), steps)

    def norm(fired):
        return [
            (t, k, os.path.basename(d) if isinstance(d, str) else d)
            for t, k, d in fired
        ]

    assert norm(plan2.fired) == norm(plan1.fired)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the healed run lands near the no-fault trajectory (one batch
    # was dropped at the nan_grad step, so equality is approximate)
    cfg, step_fn, params, opt_state, data = _setup(
        steps, watchdog=core.WatchdogConfig()
    )
    lc = LoopConfig(total_steps=steps, log_every=1)
    p_ref, _, _, hist_ref = train(step_fn, params, opt_state, data, lc)
    ref = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(p_ref)])
    got = np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(p1)])
    rel = np.linalg.norm(got - ref) / np.linalg.norm(ref)
    assert rel < 0.1, rel
    assert abs(hist1[-1][1]["loss"] - hist_ref[-1][1]["loss"]) < 0.5


def test_chaos_corrupt_checkpoint_degrades(tmp_path):
    """The corrupt_checkpoint fault lands on a committed directory; the
    rollback that later reads the directory tree must degrade past it
    (crc mismatch -> older step) instead of restoring garbage."""
    steps = 12
    cfg, step_fn, params, opt_state, data = _setup(steps)
    # saves land at steps 4/8/12: tick=5 corrupts the step-8 save — the
    # newest checkpoint when the nan_grad divergence at step 9 rolls back,
    # so the restore MUST degrade 8 -> 4
    plan = FaultPlan((
        FaultEvent("corrupt_checkpoint", tick=5),
        FaultEvent("nan_grad", tick=9),
    ))
    lc = LoopConfig(
        total_steps=steps, log_every=1, checkpoint_dir=str(tmp_path),
        save_every=4, rollback=True,
    )
    with pytest.warns(RuntimeWarning, match="corrupt"):
        p, o, step, hist = train(
            step_fn, params, opt_state, data, lc, fault_plan=plan
        )
    assert step == steps
    assert sorted(f[1] for f in plan.fired) == ["corrupt_checkpoint", "nan_grad"]
    assert np.isfinite(hist[-1][1]["loss"])
