"""Unified orthoptimizer API: parity with the pre-refactor implementations,
typed-config registry construction, tall-leaf support for every method.

The ``_ref_*`` functions below are the per-leaf update math of the
pre-refactor hand-rolled optimizers, kept verbatim as the golden reference
the migrated direction/land stages must reproduce (square and wide leaves;
tall leaves were only handled by POGO before the redesign, so tall parity
is checked against the transpose-dispatched reference)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import api, quartic, stiefel
from repro.core.api import (
    METHODS,
    OrthoState,
    orthogonal,
    orthogonal_from_config,
)

KEY = jax.random.PRNGKey(0)


def _accum(dtype):
    if jnp.issubdtype(dtype, jnp.complexfloating):
        return dtype
    return jnp.promote_types(dtype, jnp.float32)


def _sdt(dtype):
    if jnp.issubdtype(dtype, jnp.complexfloating):
        return jnp.float64 if dtype == jnp.complex128 else jnp.float32
    return dtype


# ----------------------------------------------------- pre-refactor references


def _ref_safe_eta(x, direction, eta0, eps):
    xh = jnp.conj(jnp.swapaxes(x, -1, -2))
    dh = jnp.conj(jnp.swapaxes(direction, -1, -2))
    p = x.shape[-2]
    c = x @ xh - jnp.eye(p, dtype=x.dtype)
    dm = -(x @ dh + direction @ xh)
    em = direction @ dh

    def ip(a, b):
        return jnp.sum(jnp.real(jnp.conj(a) * b), axis=(-2, -1))

    a4 = ip(em, em)
    a3 = 2.0 * ip(dm, em)
    a2 = ip(dm, dm) + 2.0 * ip(c, em)
    a1 = 2.0 * ip(c, dm)
    a0 = ip(c, c) - eps**2
    roots = quartic.solve_quartic(a4, a3, a2, a1, a0)
    real_ok = jnp.abs(jnp.imag(roots)) < 1e-5 * (1 + jnp.abs(jnp.real(roots)))
    pos = jnp.real(roots) > 0
    candidates = jnp.where(real_ok & pos, jnp.real(roots), jnp.inf)
    eta_max = jnp.min(candidates, axis=-1)
    violating = a0 > 0
    eta = jnp.minimum(eta0, eta_max)
    eta = jnp.where(violating, jnp.minimum(eta, 0.5 * eta0), eta)
    return jnp.maximum(eta, 1e-8)


def _ref_pogo(x, g, eta, lam=0.5, find_root=False):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    r = stiefel.riemannian_gradient(x32, g32)
    m = x32 - jnp.asarray(eta, jnp.float32).astype(_sdt(x32.dtype)) * r
    if find_root:
        lam_v = quartic.optimal_lambda(m, fallback=lam)
        lam_v = lam_v[..., None, None].astype(_sdt(x32.dtype))
    else:
        lam_v = jnp.asarray(lam, _sdt(x32.dtype))
    c = stiefel.gram(m)
    x_next = (1.0 + lam_v) * m - lam_v * (c @ m)
    return (x_next - x32).astype(x.dtype)


def _ref_landing(x, g, eta0, lam=1.0, eps=0.5, safe_step=True):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    r = stiefel.riemannian_gradient(x32, g32)
    n = stiefel.penalty_grad(x32)
    d = r + lam * n
    if safe_step:
        eta = _ref_safe_eta(x32, d, eta0, eps)[..., None, None]
    else:
        eta = jnp.asarray(eta0)
    eta = eta.astype(jnp.float32)
    return (-(eta * d)).astype(x.dtype)


def _ref_landing_pc(x, g, eta0, lam=0.1, eps=0.5):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    r = stiefel.riemannian_gradient(x32, g32)
    n = stiefel.penalty_grad(x32)
    rn = jnp.sqrt(jnp.sum(jnp.abs(r) ** 2, axis=(-2, -1), keepdims=True))
    nn = jnp.sqrt(jnp.sum(jnp.abs(n) ** 2, axis=(-2, -1), keepdims=True))
    lam_eff = lam * (1.0 + rn / (nn + 1e-12))
    d = r + lam_eff.astype(r.dtype) * n
    eta = _ref_safe_eta(x32, d, eta0, eps)[..., None, None].astype(jnp.float32)
    return (-(eta * d)).astype(x.dtype)


def _ref_rgd(x, g, eta, retraction="qr"):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    if retraction == "cayley":
        omega = stiefel.skew(g32 @ jnp.conj(jnp.swapaxes(x32, -1, -2)))
        x_next = stiefel.retraction_cayley(x32, -jnp.asarray(eta, jnp.float32) * omega)
    else:
        r = stiefel.riemannian_gradient(x32, g32)
        v = -jnp.asarray(eta, jnp.float32) * r
        if retraction == "qr":
            x_next = stiefel.retraction_qr(x32, v)
        elif retraction == "polar":
            x_next = stiefel.retraction_polar(x32, v)
        else:
            x_next = stiefel.project_newton_schulz(x32 + v)
    return (x_next - x32).astype(x.dtype)


def _ref_slpg(x, g, eta):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    r = g32 - stiefel.sym(x32 @ jnp.conj(jnp.swapaxes(g32, -1, -2))) @ x32
    y = x32 - jnp.asarray(eta, jnp.float32) * r
    c = y @ jnp.conj(jnp.swapaxes(y, -1, -2))
    x_next = (1.5 * y) - 0.5 * (c @ y)
    return (x_next - x32).astype(x.dtype)


def _ref_rsdm(x, g, eta, key, submanifold_dim=8):
    x32 = x.astype(_accum(x.dtype))
    g32 = g.astype(x32.dtype)
    p = x32.shape[-2]
    r = min(submanifold_dim, p)
    omega = stiefel.skew(g32 @ jnp.conj(jnp.swapaxes(x32, -1, -2)))
    u = stiefel.random_stiefel(key, (*x32.shape[:-2], r, p), x32.dtype)
    uh = jnp.conj(jnp.swapaxes(u, -1, -2))
    w = u @ omega @ uh
    eye_r = jnp.eye(r, dtype=x32.dtype)
    s = -jnp.asarray(eta, jnp.float32) * w
    o = jnp.linalg.solve(eye_r - 0.5 * s, eye_r + 0.5 * s)
    q_sub = uh @ o @ u
    proj = uh @ u
    x_next = q_sub @ x32 + x32 - proj @ x32
    return (x_next - x32).astype(x.dtype)


ETA = 0.1

REF_UPDATES = {
    "pogo": lambda x, g, key: _ref_pogo(x, g, ETA),
    "pogo_root": lambda x, g, key: _ref_pogo(x, g, ETA, find_root=True),
    "landing": lambda x, g, key: _ref_landing(x, g, ETA),
    "landing_unsafe": lambda x, g, key: _ref_landing(x, g, ETA, safe_step=False),
    "landing_pc": lambda x, g, key: _ref_landing_pc(x, g, ETA),
    "rgd_qr": lambda x, g, key: _ref_rgd(x, g, ETA, "qr"),
    "rgd_polar": lambda x, g, key: _ref_rgd(x, g, ETA, "polar"),
    "rgd_cayley": lambda x, g, key: _ref_rgd(x, g, ETA, "cayley"),
    "rgd_ns": lambda x, g, key: _ref_rgd(x, g, ETA, "newton_schulz"),
    "slpg": lambda x, g, key: _ref_slpg(x, g, ETA),
    "rsdm": lambda x, g, key: _ref_rsdm(x, g, ETA, key),
}

NEW_OPTS = {
    "pogo": lambda: orthogonal("pogo", learning_rate=ETA),
    "pogo_root": lambda: orthogonal("pogo", learning_rate=ETA, find_root=True),
    "landing": lambda: orthogonal("landing", learning_rate=ETA),
    "landing_unsafe": lambda: orthogonal("landing", learning_rate=ETA, safe_step=False),
    "landing_pc": lambda: orthogonal("landing_pc", learning_rate=ETA),
    "rgd_qr": lambda: orthogonal("rgd", learning_rate=ETA, retraction="qr"),
    "rgd_polar": lambda: orthogonal("rgd", learning_rate=ETA, retraction="polar"),
    "rgd_cayley": lambda: orthogonal("rgd", learning_rate=ETA, retraction="cayley"),
    "rgd_ns": lambda: orthogonal("rgd", learning_rate=ETA, retraction="newton_schulz"),
    "slpg": lambda: orthogonal("slpg", learning_rate=ETA),
    "rsdm": lambda: orthogonal("rsdm", learning_rate=ETA, submanifold_dim=8),
}


def _problem(shape, dtype):
    x = stiefel.random_stiefel(KEY, shape, dtype)
    g = 0.3 * stiefel.random_stiefel(jax.random.PRNGKey(1), shape, dtype)
    # start slightly off-manifold so land/safe-step stages have work to do
    x = x + jnp.asarray(0.01, dtype) * stiefel.random_stiefel(
        jax.random.PRNGKey(2), shape, dtype
    )
    return x, g


def _driver_leaf_key(seed=0):
    """The driver's per-matrix key derivation for a single-matrix tree:
    split(rng) -> stacked split(subkey, n_matrices), matrix 0's key."""
    _, subkey = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.split(subkey, 1)[0]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.complex64], ids=["f32", "c64"])
@pytest.mark.parametrize("shape", [(16, 16), (12, 24)], ids=["square", "wide"])
@pytest.mark.parametrize("name", sorted(REF_UPDATES))
def test_parity_with_pre_refactor(name, shape, dtype):
    x, g = _problem(shape, dtype)
    opt = NEW_OPTS[name]()
    state = opt.init(x)
    u_new, state = opt.update(g, state, x)
    u_ref = REF_UPDATES[name](x, g, _driver_leaf_key())
    np.testing.assert_allclose(
        np.asarray(u_new), np.asarray(u_ref), atol=5e-6, rtol=1e-5
    )


@pytest.mark.parametrize("name", sorted(REF_UPDATES))
def test_tall_leaves_work_for_every_method(name):
    """p > n leaves are constrained along the transpose for ALL methods now
    (pre-refactor: POGO only). Parity: transpose-dispatched reference."""
    wide = (10, 28)
    x_w, g_w = _problem(wide, jnp.float32)
    x_t, g_t = jnp.swapaxes(x_w, -1, -2), jnp.swapaxes(g_w, -1, -2)
    opt = NEW_OPTS[name]()
    state = opt.init(x_t)
    u_t, state = opt.update(g_t, state, x_t)
    u_ref = REF_UPDATES[name](x_w, g_w, _driver_leaf_key())
    np.testing.assert_allclose(
        np.asarray(u_t),
        np.asarray(jnp.swapaxes(u_ref, -1, -2)),
        atol=5e-6,
        rtol=1e-5,
    )
    # the tall iterate approaches/stays near the manifold of its transpose
    dist = float(stiefel.manifold_distance(jnp.swapaxes(x_t + u_t, -1, -2)))
    assert dist < 0.6, f"{name}: tall-leaf distance {dist}"


def test_parity_trajectory_pogo():
    """Multi-step parity (catches state-threading bugs, not just one step)."""
    x, g0 = _problem((12, 24), jnp.float32)
    opt = NEW_OPTS["pogo"]()
    state = opt.init(x)
    x_new = x
    x_ref = x
    for i in range(5):
        g = 0.3 * stiefel.random_stiefel(jax.random.PRNGKey(10 + i), x.shape)
        u, state = opt.update(g, state, x_new)
        x_new = x_new + u
        x_ref = x_ref + _ref_pogo(x_ref, g, ETA)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x_ref), atol=2e-5)


def test_rsdm_rng_stream_parity_multi_leaf():
    """The driver derives one stacked key array per step — split(state.rng)
    -> split(subkey, n_matrices) — indexed per MATRIX in flat-leaf order,
    so stacked leaves draw one independent submanifold per matrix and the
    stream does not depend on how leaves are bucketed into groups."""
    tree = {
        "a": stiefel.random_stiefel(KEY, (8, 20)),
        "b": stiefel.random_stiefel(jax.random.PRNGKey(3), (2, 6, 12)),
    }
    grads = jax.tree.map(
        lambda x: 0.2 * stiefel.random_stiefel(jax.random.PRNGKey(4), x.shape), tree
    )
    opt = orthogonal("rsdm", learning_rate=ETA, submanifold_dim=8, seed=0)
    state = opt.init(tree)
    u_new, state = opt.update(grads, state, tree)

    _, subkey = jax.random.split(jax.random.PRNGKey(0))
    keys = jax.random.split(subkey, 3)  # 1 matrix in "a" + 2 stacked in "b"
    u_ref = {
        "a": _ref_rsdm(tree["a"], grads["a"], ETA, keys[0]),
        "b": jnp.stack(
            [
                _ref_rsdm(tree["b"][j], grads["b"][j], ETA, keys[1 + j])
                for j in range(2)
            ]
        ),
    }
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6
        ),
        u_new,
        u_ref,
    )
    # grouping must not perturb the stream: per_leaf dispatch, same keys
    opt_pl = orthogonal(
        "rsdm", learning_rate=ETA, submanifold_dim=8, seed=0, grouping="per_leaf"
    )
    u_pl, _ = opt_pl.update(grads, opt_pl.init(tree), tree)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-6
        ),
        u_new,
        u_pl,
    )
    # second step advances the stream (updates differ from the first)
    u2, _ = opt.update(grads, state, tree)
    assert not np.allclose(np.asarray(u2["a"]), np.asarray(u_new["a"]))


# --------------------------------------------------------------- registry


def _mixed_tree():
    return {
        "ortho_wide": stiefel.random_stiefel(KEY, (6, 16)),
        "ortho_tall": jnp.swapaxes(
            stiefel.random_stiefel(jax.random.PRNGKey(5), (6, 16)), -1, -2
        ),
        "dense": jnp.ones((4, 4), jnp.float32),
    }


@pytest.mark.parametrize("name", sorted(METHODS))
def test_every_method_constructs_from_typed_config_and_steps(name):
    """Acceptance: every method builds from its typed config and runs one
    partition-wrapped step (square AND tall ortho leaves + a dense leaf)."""
    spec = METHODS[name]
    cfg = spec.config_cls(learning_rate=0.05)
    assert dataclasses.is_dataclass(cfg)
    ortho_opt = orthogonal_from_config(cfg)
    params = _mixed_tree()
    labels = {
        "ortho_wide": "orthogonal",
        "ortho_tall": "orthogonal",
        "dense": "default",
    }
    opt = optim.partition(
        {"orthogonal": ortho_opt, "default": optim.adamw(1e-3)}, labels
    )
    state = opt.init(params)
    grads = jax.tree.map(lambda x: 0.1 * jnp.ones_like(x), params)
    updates, state = opt.update(grads, state, params)
    for leaf in jax.tree.leaves(updates):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # uniform telemetry: exactly one OrthoState, finite distance
    ostates = api.ortho_states(state)
    assert len(ostates) == 1 and isinstance(ostates[0], OrthoState)
    assert float(api.max_distance(state)) < 1.0


@pytest.mark.parametrize("name", sorted(METHODS))
def test_every_method_constructs_by_name_with_base_optimizer(name):
    """Acceptance: orthogonal(method=...) works for all six — including
    rsdm, which pre-refactor rejected base_optimizer and crashed when
    selected from the trainer."""
    opt = orthogonal(
        name,
        learning_rate=0.05,
        base_optimizer=optim.chain(optim.trace(0.9)),
    )
    x = stiefel.random_stiefel(KEY, (8, 16))
    state = opt.init(x)
    g = 0.1 * jnp.ones_like(x)
    u, state = opt.update(g, state, x)
    u, state = opt.update(g, state, x)  # momentum state threads through
    assert bool(jnp.all(jnp.isfinite(u)))
    assert isinstance(state, OrthoState)
    assert state.base_state != ()


def test_unknown_method_and_bad_kwargs_raise():
    with pytest.raises(ValueError, match="unknown orthoptimizer"):
        orthogonal("muon", learning_rate=0.1)
    with pytest.raises(TypeError, match="bad kwargs"):
        orthogonal("slpg", learning_rate=0.1, lam=0.5)  # slpg has no lam
    with pytest.raises(ValueError, match="unknown retraction"):
        orthogonal("rgd", learning_rate=0.1, retraction="svd")
    with pytest.raises(ValueError, match="unregistered config"):

        @dataclasses.dataclass(frozen=True)
        class Rogue(api.OrthoConfig):
            pass

        orthogonal_from_config(Rogue())


def test_method_overrides_filters_generically():
    assert api.method_overrides("pogo", lam=0.7, find_root=None) == {"lam": 0.7}
    assert api.method_overrides("landing", lam=0.7) == {"lam": 0.7}
    assert api.method_overrides("slpg", lam=0.7, find_root=True) == {}
    with pytest.raises(ValueError):
        api.method_overrides("nope", lam=0.7)


def test_trainer_builds_every_method_without_special_cases():
    """Acceptance: the trainer dispatch is uniform — every registered
    method (rsdm included) builds through make_optimizer and takes a step
    on a mixed param tree."""
    from repro.configs import get_config
    from repro.models import ortho, transformer as tfm
    from repro.train.train_step import TrainConfig, make_optimizer

    cfg = get_config("smollm-360m", smoke=True)
    params = ortho.project_init(tfm.init_params(KEY, cfg), cfg)
    grads = jax.tree.map(lambda x: 0.01 * jnp.ones_like(x), params)
    for name in sorted(METHODS):
        tc = TrainConfig(orthoptimizer=name, pogo_learning_rate=0.1,
                         warmup_steps=1, decay_steps=10)
        optimizer = make_optimizer(cfg, tc)
        state = optimizer.init(params)
        updates, state = optimizer.update(grads, state, params)
        assert all(
            bool(jnp.all(jnp.isfinite(u))) for u in jax.tree.leaves(updates)
        ), name
        assert np.isfinite(float(api.max_distance(state))), name


def test_safety_projection_uniform_across_methods():
    """safety_project_every is a driver feature now: a drifting method
    (landing, eps-ball) snaps back onto St when the cadence hits."""
    x = stiefel.random_stiefel(KEY, (8, 24))
    opt = orthogonal(
        "landing", learning_rate=0.3, eps=0.4, safety_project_every=4
    )
    state = opt.init(x)
    g = 0.5 * stiefel.random_stiefel(jax.random.PRNGKey(6), x.shape)
    dists = []
    for _ in range(8):
        u, state = opt.update(g, state, x)
        x = x + u
        dists.append(float(stiefel.manifold_distance(x)))
    # steps 4 and 8 are projection steps: distance collapses to ~fp32 zero
    assert dists[3] < 1e-5 and dists[7] < 1e-5
    assert max(dists[:3]) > 1e-4  # and landing alone does drift


def test_schedule_learning_rate_through_driver():
    sched = lambda count: 0.1 / (1.0 + count.astype(jnp.float32))  # noqa: E731
    opt = orthogonal("pogo", learning_rate=sched)
    x = stiefel.random_stiefel(KEY, (8, 16))
    state = opt.init(x)
    g = 0.1 * jnp.ones_like(x)
    u1, state = opt.update(g, state, x)
    u2, state = opt.update(g, state, x)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(_ref_pogo(x, g, 0.1)),
                               atol=5e-6)
    assert float(jnp.max(jnp.abs(u2))) < float(jnp.max(jnp.abs(u1)))
